"""Paper §7 TTMc: planned factorize-and-fuse vs unfactorized — the paper's
"orders of magnitude vs TACO/SparseLNR" claim reduces to exactly this
schedule difference (unfactorized iterates nnz*R*S; fused iterates
nnz*S + nnz^(IJ)*R*S) — plus the xla-vs-pallas backend row on the
planned schedule (generated kernels; interpret mode off-TPU)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, tensor_suite, timeit
from repro.core import spec as S
from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 execute_unfactorized, make_executor)
from repro.core.planner import plan


def run(scale: float = 1.0, R: int = 16, Sdim: int = 16):
    rows = [("bench", "tensor", "schedule", "us_per_call",
             "speedup_vs_unfact")]
    for name, csf in tensor_suite(scale).items():
        I, J, K = csf.shape
        spec = S.ttmc3(I, J, K, R, Sdim)
        rng = np.random.default_rng(0)
        factors = {
            "U": jax.numpy.asarray(
                rng.standard_normal((J, R)).astype(np.float32)),
            "V": jax.numpy.asarray(
                rng.standard_normal((K, Sdim)).astype(np.float32))}
        arrays = CSFArrays.from_csf(csf)

        unfact = jax.jit(lambda f: execute_unfactorized(spec, arrays, f))
        t_unf = timeit(unfact, factors)
        pl_ = plan(spec, nnz_levels=csf.nnz_levels())
        ex = VectorizedExecutor(spec, pl_.path, pl_.order)
        fused = jax.jit(lambda f: ex(arrays, f))
        t_fus = timeit(fused, factors)
        pex = make_executor(spec, pl_.path, pl_.order, backend="pallas")
        pallas_fn = jax.jit(lambda f: pex(arrays, f))
        t_pal = timeit(pallas_fn, factors)
        rows.append(("ttmc", name, "unfactorized", round(t_unf * 1e6, 1),
                     1.0))
        rows.append(("ttmc", name, "spttn-planned-xla",
                     round(t_fus * 1e6, 1), round(t_unf / t_fus, 2)))
        rows.append(("ttmc", name, "spttn-planned-pallas",
                     round(t_pal * 1e6, 1), round(t_unf / t_pal, 2)))
        a, b = np.asarray(unfact(factors)), np.asarray(fused(factors))
        c = np.asarray(pallas_fn(factors))
        assert np.allclose(a, b, atol=1e-2 * max(1.0, np.abs(a).max()))
        assert np.allclose(a, c, atol=1e-2 * max(1.0, np.abs(a).max()))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
