"""Paper Fig 10c: impact of index order — the vector-intermediate order
(i,j,k,s)/(i,j,s,r) offloads innermost dense loops to BLAS/MXU (one fused
einsum), while the scalar-intermediate order (i,j,s,k) forces a sparse
innermost loop.  We execute both literally: the vectorized engine for the
BLAS-able order, and a lax.fori_loop over the dense index emulating the
scalar-intermediate loop structure."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import spec as S
from repro.core.cost import ConstrainedBlas
from repro.core.executor import CSFArrays, VectorizedExecutor
from repro.core.order_dp import OrderDP
from repro.core.paths import min_depth_paths
from repro.sparse import build_csf, random_sparse


def run(N: int = 256, R: int = 32, Sdim: int = 32, density: float = 1e-3):
    spec = S.ttmc3(N, N, N, R, Sdim)
    T = random_sparse((N, N, N), density, seed=9)
    csf = build_csf(T)
    rng = np.random.default_rng(0)
    factors = {"U": jnp.asarray(rng.standard_normal((N, R)).astype(np.float32)),
               "V": jnp.asarray(rng.standard_normal((N, Sdim)).astype(np.float32))}
    arrays = CSFArrays.from_csf(csf)

    # pick the T.V-first path; the BLAS-friendly order
    path = next(p for p in min_depth_paths(spec)
                if "(T.V)" in p[0].out.name)
    blas_order = OrderDP(path, ConstrainedBlas(2), spec.dims,
                         spec.sparse_indices).solve().order

    ex = VectorizedExecutor(spec, path, blas_order)
    fn_blas = jax.jit(lambda f: ex(arrays, f))
    t_blas = timeit(fn_blas, factors)

    # scalar-intermediate emulation: loop over s, contract per iteration
    vals = arrays.values
    k_at = arrays.fiber_coord[3][2]
    seg2 = arrays.seg[(3, 2)]
    j_of_f2 = arrays.fiber_coord[2][1]
    i_of_f2 = arrays.fiber_coord[2][0]
    nf2 = arrays.nfib[2]
    I = spec.dims["i"]

    def scalar_nest(f):
        U, V = f["U"], f["V"]

        def body(s, out):
            x = jax.ops.segment_sum(vals * V[k_at, s], seg2,
                                    num_segments=nf2)       # scalar X per f2
            contrib = x[:, None] * U[j_of_f2]               # (nf2, R)
            outs = jnp.zeros((I, R), jnp.float32).at[i_of_f2].add(contrib)
            return out.at[:, :, s].set(outs)

        return jax.lax.fori_loop(
            0, Sdim, body, jnp.zeros((I, R, Sdim), jnp.float32))

    fn_scalar = jax.jit(scalar_nest)
    t_scalar = timeit(fn_scalar, factors)

    a, b = np.asarray(fn_blas(factors)), np.asarray(fn_scalar(factors))
    assert np.allclose(a, b, atol=1e-2 * max(1.0, np.abs(a).max()))
    rows = [("bench", "order", "us_per_call", "speedup"),
            ("index_order", "scalar-intermediate(i,j,s,k)",
             round(t_scalar * 1e6, 1), 1.0),
            ("index_order", "vector-intermediate(i,j,k,s)+BLAS",
             round(t_blas * 1e6, 1), round(t_scalar / t_blas, 2))]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
