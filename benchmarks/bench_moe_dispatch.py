"""Framework integration: MoE dispatch as an SpTTN — the planner's grouped
(factorize-and-fuse) schedule vs the unfactorized one-hot einsum."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs import get_reduced
from repro.models.moe import choose_dispatch, moe_apply, moe_init


def run(T: int = 512):
    cfg = get_reduced("granite-moe-1b-a400m")
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, T, cfg.d_model))

    fn_onehot = jax.jit(
        lambda xx: moe_apply(p, cfg, xx, deterministic_dispatch="onehot")[0])
    fn_grouped = jax.jit(
        lambda xx: moe_apply(p, cfg, xx, deterministic_dispatch="grouped")[0])
    t_o = timeit(fn_onehot, x)
    t_g = timeit(fn_grouped, x)
    picked = choose_dispatch(4 * T, cfg.moe.n_experts, cfg.moe.top_k,
                             64, cfg.d_model)
    rows = [("bench", "schedule", "us_per_call", "speedup", "planner_pick"),
            ("moe", "onehot(unfactorized)", round(t_o * 1e6, 1), 1.0, ""),
            ("moe", "grouped(spttn-planned)", round(t_g * 1e6, 1),
             round(t_o / t_g, 2), picked)]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
