"""Benchmark harness entry point — one benchmark per paper table/figure.

  Fig 8   -> bench_mttkrp        Fig 9c/§7 TTTP -> bench_tttp
  §7 TTMc -> bench_ttmc          Fig 10a        -> bench_tttc
  Fig 10c -> bench_index_order   Alg 1          -> bench_search
  Fig 9/10b -> bench_strong_scaling (opt-in: SCALING=1, spawns subprocesses)
  MoE-SpTTN integration          -> bench_moe_dispatch

Prints ``name,...,us_per_call,derived`` CSV rows.  SCALE env var shrinks or
grows tensor sizes (default 0.5 keeps the suite under ~2 min on CPU).
"""
from __future__ import annotations

import os
import traceback


def main() -> None:
    scale = float(os.environ.get("SCALE", "0.5"))
    from benchmarks import (bench_index_order, bench_moe_dispatch,
                            bench_mttkrp, bench_search, bench_strong_scaling,
                            bench_tttc, bench_tttp, bench_ttmc)

    suites = [
        ("mttkrp", lambda: bench_mttkrp.run(scale=scale)),
        ("ttmc", lambda: bench_ttmc.run(scale=scale)),
        ("tttp", lambda: bench_tttp.run(scale=scale)),
        ("tttc", lambda: bench_tttc.run()),
        ("index_order", lambda: bench_index_order.run(
            N=max(64, int(256 * scale)))),
        ("search", bench_search.run),
        ("autotune", bench_search.run_autotune),
        ("moe_dispatch", bench_moe_dispatch.run),
    ]
    if os.environ.get("SCALING", "0") == "1":
        suites.append(("strong_scaling", bench_strong_scaling.run))

    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"{name},ERROR", flush=True)


if __name__ == "__main__":
    main()
