"""Benchmark harness entry point — one benchmark per paper table/figure.

  Fig 8   -> bench_mttkrp        Fig 9c/§7 TTTP -> bench_tttp
  §7 TTMc -> bench_ttmc          Fig 10a        -> bench_tttc
  Fig 10c -> bench_index_order   Alg 1          -> bench_search
  Fig 9/10b -> bench_strong_scaling (1..8 fake devices x both shard_map
               engines — XLA collective and stacked Pallas; subprocesses)
  MoE-SpTTN integration          -> bench_moe_dispatch
  §5.2 + DESIGN.md §7            -> bench_dist (1-vs-N tuned plan replay)

Prints ``name,...,us_per_call,derived`` CSV rows.  SCALE env var shrinks or
grows tensor sizes (default 0.5 keeps the suite under ~2 min on CPU).

CI contract (the bench-smoke lane):
  * any suite raising makes the process exit nonzero — a broken benchmark
    fails the build instead of rotting silently;
  * BENCH_JSON=path writes per-row medians as JSON
    ``{suite: {"tensor|schedule": us_per_call}}`` for the regression gate
    (scripts/check_bench_regression.py against the committed baseline).
"""
from __future__ import annotations

import json
import os
import traceback


def medians(results: dict) -> dict:
    """Extract ``{suite: {row_key: us_per_call}}`` from the row lists the
    suites return.  Only rows under a ``us_per_call`` header participate —
    search-phase timings (``ms`` columns) are too machine-noisy to gate."""
    out: dict[str, dict[str, float]] = {}
    for suite, rows in results.items():
        if not isinstance(rows, list) or not rows:
            continue
        header = rows[0]
        if "us_per_call" not in header:
            continue
        idx = list(header).index("us_per_call")
        entries = {}
        for row in rows[1:]:
            try:
                entries["|".join(str(x) for x in row[:idx])] = float(row[idx])
            except (TypeError, ValueError):
                continue
        if entries:
            out[suite] = entries
    return out


def main() -> int:
    scale = float(os.environ.get("SCALE", "0.5"))
    from benchmarks import (bench_dist, bench_index_order,
                            bench_moe_dispatch, bench_mttkrp,
                            bench_outofcore, bench_search,
                            bench_serve_latency, bench_strong_scaling,
                            bench_ttmc, bench_tttc, bench_tttp)

    suites = [
        ("mttkrp", lambda: bench_mttkrp.run(scale=scale)),
        ("ttmc", lambda: bench_ttmc.run(scale=scale)),
        ("tttp", lambda: bench_tttp.run(scale=scale)),
        ("tttc", lambda: bench_tttc.run()),
        ("index_order", lambda: bench_index_order.run(
            N=max(64, int(256 * scale)))),
        ("search", bench_search.run),
        ("autotune", bench_search.run_autotune),
        ("moe_dispatch", bench_moe_dispatch.run),
        ("dist", lambda: bench_dist.run(scale=scale)),
        ("serve_latency", bench_serve_latency.run),
        ("outofcore", lambda: bench_outofcore.run(scale=scale)),
        ("strong_scaling", lambda: bench_strong_scaling.run(scale=scale)),
    ]

    results: dict[str, object] = {}
    failed: list[str] = []
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            results[name] = fn()
        except Exception:
            traceback.print_exc()
            print(f"{name},ERROR", flush=True)
            failed.append(name)

    json_path = os.environ.get("BENCH_JSON")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(medians(results), f, indent=1, sort_keys=True)
        print(f"# medians -> {json_path}", flush=True)

    if failed:
        print(f"# FAILED suites: {','.join(failed)}", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
