"""Distributed plan replay: 1-vs-N simulated devices (DESIGN.md §7).

Row labels: ``tuned-single`` is the single-device autotuned
``execute_plan`` baseline; ``collective`` the shard_map engine running
the model-picked plan with psum (deterministic row — no measurement in
the loop); ``tuned-replay`` the per-shard path (each shard through its
own cached tuned winner, host-side sum).  Host-CPU fake devices emulate the
collective structure; wall-clock on one host is NOT hardware scaling —
the rows exist so the distributed path sits in the perf trajectory
(BENCH_pr3.json) and a schedule regression in either engine trips the
CI gate.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SNIPPET = """
import json
import os
import tempfile
import time
import numpy as np
import jax
import jax.numpy as jnp
from repro.autotune import TunerConfig
from repro.core import spec as S
from repro.core.executor import CSFArrays, make_executor
from repro.core.planner import plan
from repro.distributed import make_distributed, make_distributed_tuned
from repro.sparse import build_csf, random_sparse

n = len(jax.devices())
N = int(os.environ["BD_N"])
R = 16
cfg = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                  warmup=1, repeats=2)
rng = np.random.default_rng(0)


def bench(fn):
    for _ in range(2):
        out = fn()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else out
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = fn()
        if hasattr(out, "block_until_ready"):
            jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


rows = []
for name, spec in [("mttkrp", S.mttkrp(N, N, N, R)),
                   ("ttmc", S.ttmc3(N, N, N, R, 8))]:
    T = random_sparse((N, N, N), 5e-3, seed=2)
    csf = build_csf(T)
    factors = {t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}
    cache = tempfile.mkdtemp()
    if n == 1:
        tuned = plan(spec, autotune=True, cache_dir=cache, csf=csf,
                     tuner=cfg)
        ex = make_executor(spec, tuned.path, tuned.order,
                           backend=tuned.backend)
        arrays = CSFArrays.from_csf(csf)
        fn = jax.jit(lambda f, ex=ex, a=arrays: ex(a, f))
        rows.append((name, "tuned-single", n, bench(lambda: fn(factors))))
    else:
        mesh = jax.make_mesh((n,), ("data",))
        # collective shard_map engine replaying one (model-picked) plan
        pl_ = plan(spec, nnz_levels=csf.nnz_levels())
        coll = make_distributed(spec, pl_, T, mesh, {0: "data"})
        rows.append((name, "collective", n,
                     bench(lambda: coll(factors))))
        # per-shard tuned replay (each shard through its cached winner)
        replay = make_distributed_tuned(spec, T, mesh, {0: "data"},
                                        cache_dir=cache, tuner=cfg,
                                        prefer_collective=False)
        rows.append((name, "tuned-replay", n,
                     bench(lambda: replay(factors))))
print(json.dumps(rows))
"""


def run(scale: float = 1.0):
    rows = [("bench", "kernel", "schedule", "devices", "us_per_call")]
    N = max(32, int(128 * scale))
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for n in (1, 4):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        env["BD_N"] = str(N)
        out = subprocess.run([sys.executable, "-c", SNIPPET], env=env,
                             capture_output=True, text=True, timeout=600)
        if out.returncode != 0:
            raise RuntimeError(
                f"bench_dist subprocess (n={n}) failed:\n"
                f"{out.stderr[-2000:]}")
        for kernel, schedule, devices, us in json.loads(
                out.stdout.strip().splitlines()[-1]):
            rows.append(("dist", kernel, schedule, devices, round(us, 1)))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
