"""Paper Fig 10a: order-6 TTTc at 1% and 0.1% density (N scaled for CPU),
R=16 — planned schedule wall-clock + op counts."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, timeit
from repro.core import spec as S
from repro.core.executor import CSFArrays, VectorizedExecutor
from repro.core.planner import plan
from repro.sparse import build_csf, random_sparse


def run(N: int = 16, R: int = 8):
    rows = [("bench", "density", "us_per_call", "plan_flops_est")]
    for density in (1e-2, 1e-3):
        spec = S.tttc6(N, R)
        T = random_sparse((N,) * 6, density, seed=4)
        csf = build_csf(T)
        rng = np.random.default_rng(0)
        factors = {}
        for t in spec.inputs:
            if not t.is_sparse:
                factors[t.name] = jax.numpy.asarray(rng.standard_normal(
                    [spec.dims[i] for i in t.indices]).astype(np.float32))
        pl_ = plan(spec, nnz_levels=csf.nnz_levels(), max_paths=64)
        arrays = CSFArrays.from_csf(csf)
        ex = VectorizedExecutor(spec, pl_.path, pl_.order)
        fn = jax.jit(lambda f: ex(arrays, f))
        t = timeit(fn, factors)
        rows.append(("tttc6", density, round(t * 1e6, 1),
                     f"{pl_.flops:.3g}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
