"""Shared benchmark utilities: timing, synthetic tensors, CSV output."""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import long_fiber_sparse


def timeit(fn, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median wall-clock seconds per call (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def tensor_suite(scale: float = 1.0):
    """Synthetic stand-ins for the paper's datasets (FROSTT is offline):
    nell-2-like (skewed), uniform, and a small dense-ish one."""
    s = lambda x: max(8, int(x * scale))
    return {
        "nell2-like": build_csf(random_sparse(
            (s(1024), s(512), s(256)), 3e-4, seed=1, distribution="frostt")),
        "uniform-3d": build_csf(random_sparse(
            (s(512), s(512), s(512)), 1e-4, seed=2)),
        "dense-ish": build_csf(random_sparse(
            (s(96), s(96), s(96)), 5e-3, seed=3)),
        # long (i,j)-fibers: nnz >> nnz^(IJ), the factorize-and-fuse regime
        "long-fiber": build_csf(long_fiber_sparse(
            (s(2048), s(2048), s(4096)), n_fibers=s(4096),
            fiber_len=max(4, s(24)), seed=5)),
    }


def emit(rows: list[tuple]) -> None:
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)
