"""Paper Fig 8: single-node MTTKRP — unfactorized (TACO-default) vs the
SpTTN-planned factorize-and-fuse schedule, R=64, plus the Pallas kernel
path (interpret mode; XLA path is the CPU-honest number)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, tensor_suite, timeit
from repro.core import spec as S
from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 execute_unfactorized)
from repro.core.planner import plan
from repro.kernels import ops


def run(scale: float = 1.0, R: int = 64):
    rows = [("bench", "tensor", "schedule", "us_per_call", "speedup_vs_unfact")]
    for name, csf in tensor_suite(scale).items():
        I, J, K = csf.shape
        spec = S.mttkrp(I, J, K, R)
        rng = np.random.default_rng(0)
        factors = {"B": jax.numpy.asarray(
            rng.standard_normal((J, R)).astype(np.float32)),
            "C": jax.numpy.asarray(
                rng.standard_normal((K, R)).astype(np.float32))}
        arrays = CSFArrays.from_csf(csf)

        unfact = jax.jit(lambda f: execute_unfactorized(spec, arrays, f))
        t_unf = timeit(unfact, factors)

        pl_ = plan(spec, nnz_levels=csf.nnz_levels())
        ex = VectorizedExecutor(spec, pl_.path, pl_.order)
        fused = jax.jit(lambda f: ex(arrays, f))
        t_fus = timeit(fused, factors)

        rows.append(("mttkrp", name, "unfactorized",
                     round(t_unf * 1e6, 1), 1.0))
        rows.append(("mttkrp", name, "spttn-planned",
                     round(t_fus * 1e6, 1), round(t_unf / t_fus, 2)))

        # correctness cross-check while we're here
        a = np.asarray(unfact(factors))
        b = np.asarray(fused(factors))
        assert np.allclose(a, b, atol=1e-2 * max(1.0, np.abs(a).max()))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
