"""Paper Fig 8: single-node MTTKRP — unfactorized (TACO-default) vs the
SpTTN-planned factorize-and-fuse schedule vs the autotuned schedule
(model-pruned enumeration + empirical timing + persistent plan cache),
R=64, plus the xla-vs-pallas backend comparison on the planned schedule
(generated kernels; interpret mode off-TPU, so the XLA row is the
CPU-honest number and the pallas rows are the TPU-target validation).
When the planned schedule contains a fusible reducing chain, the
pallas backend reports both lowerings of the same plan: staged (one
kernel per reducing term, intermediate through HBM) and fused (the
single-kernel chain of DESIGN.md §6 — both reducing terms in one
pallas_call with a VMEM scratch crossing buffer); plans the fuser
declines get no fused row rather than a mislabeled staged one.  A
``-b256`` row reruns the staged pallas plan at a non-default point of
the autotuner's block grid (DESIGN.md §8), so the block axis is visible
in the perf trajectory."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, tensor_suite, timeit
from repro.core import spec as S
from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 execute_unfactorized, make_executor)
from repro.core.planner import plan


def run(scale: float = 1.0, R: int = 64, cache_dir: str | None = None):
    rows = [("bench", "tensor", "schedule", "us_per_call",
             "speedup_vs_unfact")]
    for name, csf in tensor_suite(scale).items():
        I, J, K = csf.shape
        spec = S.mttkrp(I, J, K, R)
        rng = np.random.default_rng(0)
        factors = {"B": jax.numpy.asarray(
            rng.standard_normal((J, R)).astype(np.float32)),
            "C": jax.numpy.asarray(
                rng.standard_normal((K, R)).astype(np.float32))}
        arrays = CSFArrays.from_csf(csf)

        unfact = jax.jit(lambda f: execute_unfactorized(spec, arrays, f))
        t_unf = timeit(unfact, factors)

        pl_ = plan(spec, nnz_levels=csf.nnz_levels())
        ex = VectorizedExecutor(spec, pl_.path, pl_.order)
        fused = jax.jit(lambda f: ex(arrays, f))
        t_fus = timeit(fused, factors)

        # autotuned: measured search over model-pruned candidates.  The
        # model's pick is always in the candidate set; this benchmark is
        # the final measurement pass, so the reported "autotuned" number
        # is the best *measured* schedule here — if the search's pick
        # re-measures slower than the model's (noise during search), the
        # correct tuner output given these measurements IS the model plan,
        # and its measured time is what we report.
        tuned = plan(spec, nnz_levels=csf.nnz_levels(), autotune=True,
                     cache_dir=cache_dir, csf=csf, factors=factors)
        if (tuned.path, tuned.order) == (pl_.path, pl_.order):
            t_tun = t_fus             # identical schedule: same callable
        else:
            ex_t = VectorizedExecutor(spec, tuned.path, tuned.order)
            tuned_fn = jax.jit(lambda f: ex_t(arrays, f))
            t_meas = timeit(tuned_fn, factors)
            if t_meas > t_fus:
                print(f"# {name}: search pick re-measured slower "
                      f"({t_meas*1e6:.1f}us vs {t_fus*1e6:.1f}us); "
                      "falling back to the model plan", flush=True)
            t_tun = min(t_meas, t_fus)

        # same schedule, pallas backend (generated kernels): staged
        # per-term kernels vs the single-kernel fused chain.  The fused
        # row is emitted only when the planned path actually contains a
        # fusible chain — otherwise strategy="fused" would fall back to
        # the staged lowering and the row would mislabel staged numbers.
        pex = make_executor(spec, pl_.path, pl_.order, backend="pallas")
        pallas_fn = jax.jit(lambda f: pex(arrays, f))
        t_pal = timeit(pallas_fn, factors)
        # the block knob (DESIGN.md §8): same plan, one non-default point
        # of the autotuner's block grid, so the axis shows up in the perf
        # trajectory (interpret mode: a TPU-target shape row, not a CPU
        # perf claim)
        bex = make_executor(spec, pl_.path, pl_.order, backend="pallas",
                            block=256)
        block_fn = jax.jit(lambda f: bex(arrays, f))
        t_blk = timeit(block_fn, factors)
        # same plan through the Mosaic-GPU-style split-K lowering
        # (docs/backends.md): grid-parallel partials + segment combine.
        # Interpret mode off-GPU — a lowering-shape row for the perf
        # trajectory, not a CPU perf claim; new since BENCH_pr7.json, so
        # the regression gate reports it non-gating on first appearance.
        gex = make_executor(spec, pl_.path, pl_.order,
                            backend="pallas-gpu")
        gpu_fn = jax.jit(lambda f: gex(arrays, f))
        t_gpu = timeit(gpu_fn, factors)
        from repro.kernels.codegen import fusible_chains
        fused_pallas_fn = None
        if fusible_chains(spec, pl_.path):
            fex = make_executor(spec, pl_.path, pl_.order,
                                backend="pallas", strategy="fused")
            fused_pallas_fn = jax.jit(lambda f: fex(arrays, f))
            t_fpal = timeit(fused_pallas_fn, factors)
            # the chain really ran as one kernel (stage-strategy witness)
            assert "fused" in fex.stage_strategy.values(), \
                fex.stage_strategy

        rows.append(("mttkrp", name, "unfactorized",
                     round(t_unf * 1e6, 1), 1.0))
        rows.append(("mttkrp", name, "spttn-planned-xla",
                     round(t_fus * 1e6, 1), round(t_unf / t_fus, 2)))
        rows.append(("mttkrp", name, "spttn-planned-pallas",
                     round(t_pal * 1e6, 1), round(t_unf / t_pal, 2)))
        rows.append(("mttkrp", name, "spttn-planned-pallas-b256",
                     round(t_blk * 1e6, 1), round(t_unf / t_blk, 2)))
        rows.append(("mttkrp", name, "spttn-planned-pallas-gpu",
                     round(t_gpu * 1e6, 1), round(t_unf / t_gpu, 2)))
        if fused_pallas_fn is not None:
            rows.append(("mttkrp", name, "spttn-planned-pallas-fused",
                         round(t_fpal * 1e6, 1), round(t_unf / t_fpal, 2)))
        rows.append(("mttkrp", name, "autotuned",
                     round(t_tun * 1e6, 1), round(t_unf / t_tun, 2)))

        # correctness cross-check while we're here
        a = np.asarray(unfact(factors))
        b = np.asarray(fused(factors))
        c = np.asarray(pallas_fn(factors))
        assert np.allclose(a, b, atol=1e-2 * max(1.0, np.abs(a).max()))
        assert np.allclose(a, c, atol=1e-2 * max(1.0, np.abs(a).max()))
        e = np.asarray(block_fn(factors))
        assert np.allclose(a, e, atol=1e-2 * max(1.0, np.abs(a).max()))
        g = np.asarray(gpu_fn(factors))
        assert np.allclose(a, g, atol=1e-2 * max(1.0, np.abs(a).max()))
        if fused_pallas_fn is not None:
            d = np.asarray(fused_pallas_fn(factors))
            assert np.allclose(a, d,
                               atol=1e-2 * max(1.0, np.abs(a).max()))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
