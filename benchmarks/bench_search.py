"""Algorithm 1's complexity claim: DP vs exhaustive enumeration wall-clock
(and agreement of optima) as kernel size grows — O(N^3 2^m m) vs
O(prod |I_i|!).  Plus the autotuner: cold measured search vs warm plan-cache
load, and tuned-vs-model measured runtime."""
from __future__ import annotations

import tempfile
import time

from benchmarks.common import emit
from repro.core import spec as S
from repro.core.cost import MaxBufferSize
from repro.core.enumerate import brute_force_optimal
from repro.core.order_dp import OrderDP
from repro.core.paths import min_depth_paths


def run_autotune(cache_dir: str | None = None):
    """Cold search vs warm cache vs model-only planning, small MTTKRP."""
    from repro.core.planner import plan

    cache_dir = cache_dir or tempfile.mkdtemp(prefix="spttn-plans-")
    rows = [("bench", "kernel", "phase", "ms", "candidate_execs",
             "tuned_over_model_runtime")]
    spec = S.mttkrp(64, 48, 32, 16)
    for phase in ("cold", "warm"):
        t0 = time.perf_counter()
        p = plan(spec, autotune=True, cache_dir=cache_dir)
        ms = (time.perf_counter() - t0) * 1e3
        st = p.stats
        ratio = ""
        if st.best_seconds and st.model_seconds:
            ratio = round(st.best_seconds / st.model_seconds, 3)
        rows.append(("autotune", "mttkrp(64,48,32,16)", phase,
                     round(ms, 1), st.executions, ratio))
    assert rows[-1][4] == 0, "warm run must not execute candidates"
    emit(rows)
    return rows


def run():
    cases = [
        ("mttkrp(m=4)", S.mttkrp(8, 8, 8, 4)),
        ("ttmc3(m=5)", S.ttmc3(8, 8, 8, 4, 4)),
        ("ttmc4(m=7)", S.ttmc4(8, 8, 8, 8, 4, 4, 4)),
        ("tttp3(m=4)", S.tttp3(8, 8, 8, 4)),
    ]
    rows = [("bench", "kernel", "dp_ms", "bruteforce_ms", "speedup",
             "optima_agree")]
    cost = MaxBufferSize()
    for name, spec in cases:
        path = min_depth_paths(spec, max_paths=1)[0]
        t0 = time.perf_counter()
        dp = OrderDP(path, cost, spec.dims, spec.sparse_indices).solve()
        t_dp = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, bf_cost = brute_force_optimal(path, cost, spec.dims,
                                         spec.sparse_indices)
        t_bf = time.perf_counter() - t0
        rows.append(("search", name, round(t_dp * 1e3, 2),
                     round(t_bf * 1e3, 2), round(t_bf / max(t_dp, 1e-9), 1),
                     abs(dp.cost - bf_cost) < 1e-9))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
    run_autotune()
