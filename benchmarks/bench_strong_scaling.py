"""Paper Fig 9/10b: strong scaling of the distributed SpTTN (shard_map).

One subprocess per device count (fake host-CPU devices), each emitting a
JSON line per engine: ``collective`` is the XLA shard_map engine
(:func:`make_distributed`), ``collective-pallas`` the stacked generated-
kernel engine (:func:`make_distributed_pallas`, interpret mode on CPU —
its wall-clock is validation-grade, the row exists so the stacked path
sits in the perf trajectory).  Host wall-clock on one host is NOT
hardware scaling; the artifact of record is the per-device work (nnz)
printed alongside.

Error discipline (the bench-smoke CI lane): a failed device count
reports out-of-band on stderr and is dropped from the table — rows stay
schema-clean (``us_per_call`` is always a number) so the medians JSON
and the regression gate never ingest garbage.  Only if EVERY device
count fails does the suite raise.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SNIPPET = """
import json
import os
import time
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import spec as S
from repro.core.planner import plan
from repro.distributed.spttn_dist import (make_distributed,
                                          make_distributed_pallas)
from repro.sparse import build_csf, random_sparse

n = len(jax.devices())
N = int(os.environ["BSS_N"])
R = 16
spec = S.mttkrp(N, N, N, R)
T = random_sparse((N, N, N), 10.0 / (N * N), seed=2)
csf = build_csf(T)
rng = np.random.default_rng(0)
factors = {"B": jnp.asarray(rng.standard_normal((N, R)).astype(np.float32)),
           "C": jnp.asarray(rng.standard_normal((N, R)).astype(np.float32))}
pl = plan(spec, nnz_levels=csf.nnz_levels())
mesh = jax.make_mesh((n,), ("data",))

def bench(dist):
    out = dist(factors); jax.block_until_ready(out)
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); out = dist(factors)
        jax.block_until_ready(out); ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)

for mode, make in [
        ("collective", make_distributed),
        ("collective-pallas", make_distributed_pallas)]:
    dist = make(spec, pl, T, mesh, mode_axis={0: "data"})
    print(json.dumps({"mode": mode, "n": n, "us": bench(dist),
                      "nnz": int(T.nnz)}))
"""


def run(scale: float = 1.0):
    rows = [("bench", "mode", "devices", "us_per_call", "nnz")]
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = []
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        env["BSS_N"] = str(max(64, int(256 * scale)))
        out = subprocess.run([sys.executable, "-c", SNIPPET], env=env,
                             capture_output=True, text=True, timeout=600)
        if out.returncode != 0:
            failures.append(n)
            print(f"# strong_scaling: {n} devices FAILED\n"
                  f"{out.stderr[-2000:]}", file=sys.stderr, flush=True)
            continue
        for line in out.stdout.strip().splitlines():
            if not line.startswith("{"):
                continue
            data = json.loads(line)
            rows.append(("strong_scaling", data["mode"], data["n"],
                         round(data["us"], 1), data["nnz"]))
    if len(failures) == 4:
        raise RuntimeError(
            "strong_scaling: every device count failed (see stderr)")
    emit(rows)
    return rows


if __name__ == "__main__":
    run(scale=float(os.environ.get("SCALE", "1.0")))
