"""Paper Fig 9/10b: strong scaling of the distributed SpTTN (shard_map).
Host-CPU fake devices emulate the collective structure; wall-clock scaling
on one host is NOT hardware scaling — the artifact of record is the
per-device work + collective bytes, which this prints alongside."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SNIPPET = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.core import spec as S
from repro.core.planner import plan
from repro.distributed.spttn_dist import make_distributed
from repro.sparse import build_csf, random_sparse

n = len(jax.devices())
mesh = jax.make_mesh((n,), ("data",))
N, R = 512, 32
spec = S.mttkrp(N, N, N, R)
T = random_sparse((N, N, N), 1e-4, seed=2)
csf = build_csf(T)
rng = np.random.default_rng(0)
factors = {"B": jnp.asarray(rng.standard_normal((N, R)).astype(np.float32)),
           "C": jnp.asarray(rng.standard_normal((N, R)).astype(np.float32))}
pl = plan(spec, nnz_levels=csf.nnz_levels())
dist = make_distributed(spec, pl, T, mesh, mode_axis={0: "data"})
out = dist(factors); jax.block_until_ready(out)
ts = []
for _ in range(5):
    t0 = time.perf_counter(); out = dist(factors)
    jax.block_until_ready(out); ts.append(time.perf_counter() - t0)
print(json.dumps({"n": n, "us": float(np.median(ts) * 1e6),
                  "nnz": int(T.nnz)}))
"""


def run():
    rows = [("bench", "devices", "us_per_call", "nnz")]
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.path.join(repo, "src")
        out = subprocess.run([sys.executable, "-c", SNIPPET], env=env,
                             capture_output=True, text=True, timeout=600)
        if out.returncode != 0:
            rows.append(("strong_scaling", n, "ERROR", out.stderr[-200:]))
            continue
        data = json.loads(out.stdout.strip().splitlines()[-1])
        rows.append(("strong_scaling", n, round(data["us"], 1), data["nnz"]))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
