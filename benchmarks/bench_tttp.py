"""Paper Fig 9(c)/§7 TTTP (generalized SDDMM): planned vs unfactorized vs
the generated Pallas backend on the planned schedule, plus the leaf-kernel
XLA formulation (interpret mode on CPU; TPU target)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, tensor_suite, timeit
from repro.core import spec as S
from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 execute_unfactorized, make_executor)
from repro.core.planner import plan


def run(scale: float = 1.0, R: int = 32):
    rows = [("bench", "tensor", "schedule", "us_per_call",
             "speedup_vs_unfact")]
    for name, csf in tensor_suite(scale).items():
        I, J, K = csf.shape
        spec = S.tttp3(I, J, K, R)
        rng = np.random.default_rng(0)
        factors = {
            "U": jax.numpy.asarray(
                rng.standard_normal((I, R)).astype(np.float32)),
            "V": jax.numpy.asarray(
                rng.standard_normal((J, R)).astype(np.float32)),
            "W": jax.numpy.asarray(
                rng.standard_normal((K, R)).astype(np.float32))}
        arrays = CSFArrays.from_csf(csf)
        unfact = jax.jit(lambda f: execute_unfactorized(spec, arrays, f))
        t_unf = timeit(unfact, factors)
        pl_ = plan(spec, nnz_levels=csf.nnz_levels())
        ex = VectorizedExecutor(spec, pl_.path, pl_.order)
        fused = jax.jit(lambda f: ex(arrays, f))
        t_fus = timeit(fused, factors)
        # leaf-kernel XLA path with precomputed coordinate gathers (jitted)
        fc = csf.fiber_coords(3)
        iidx, jidx, kidx = (jax.numpy.asarray(fc[:, m]) for m in range(3))
        vals = jax.numpy.asarray(csf.values)
        from repro.kernels import ref as kref
        leaf = jax.jit(lambda f: kref.tttp_ref(
            vals, f["U"][iidx], f["V"][jidx], f["W"][kidx]))
        t_leaf = timeit(leaf, factors)
        pex = make_executor(spec, pl_.path, pl_.order, backend="pallas")
        pallas_fn = jax.jit(lambda f: pex(arrays, f))
        t_pal = timeit(pallas_fn, factors)
        rows.append(("tttp", name, "unfactorized", round(t_unf * 1e6, 1),
                     1.0))
        rows.append(("tttp", name, "spttn-planned-xla",
                     round(t_fus * 1e6, 1), round(t_unf / t_fus, 2)))
        rows.append(("tttp", name, "spttn-planned-pallas",
                     round(t_pal * 1e6, 1), round(t_unf / t_pal, 2)))
        rows.append(("tttp", name, "leaf-kernel-xla",
                     round(t_leaf * 1e6, 1), round(t_unf / t_leaf, 2)))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
