"""Latency SLOs for the serving plan-cache hot path (DESIGN.md §9).

A serving stream hands the planner a *perturbed* MoE routing pattern per
request.  Without profile bucketing every request is a cold autotune; with
it, one search serves the whole bucket.  This bench measures per-request
end-to-end latency (routing COO -> CSF -> plan resolution -> dispatch
execution) for the three cache tiers and emits p50/p99 rows:

    serve,cold-miss,...    exact-only keying: every pattern re-searches
    serve,exact-hit,...    the same pattern repeated (in-process map hit)
    serve,bucket-hit,...   perturbed stream under log2 bucketing

SLOs asserted here (and gated by acceptance): bucket-hit p50 within 5x of
exact-hit p50, both >= 10x below cold-miss p50, and bucket-hit outputs
match freshly tuned plans at 1e-5.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

import jax

from benchmarks.common import emit
from repro.autotune.tuner import TunerConfig
from repro.serve import PlanService, moe_routing_coo

# small-search knobs shared by every tier so cold-vs-hot compares plan
# RESOLUTION cost, not search-budget differences
_SEARCH = dict(max_paths=4, max_candidates=4, orders_per_path=1,
               warmup=0, repeats=1)


def _routing(N, E, k, C, seed):
    r = np.random.default_rng(seed)
    idx = np.argsort(-r.standard_normal((N, E)), axis=1)[:, :k]
    return moe_routing_coo(idx, E, C)


def _request_us(svc, coo, x):
    t0 = time.perf_counter()
    out, st = svc.dispatch(coo, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6, out, st


def run(stream: int = 32):
    N, E, k, C, D = 64, 8, 2, 16, 64
    rng = np.random.default_rng(0)
    x = rng.standard_normal((N, D)).astype(np.float32)
    patterns = [_routing(N, E, k, C, 100 + s) for s in range(stream)]

    # --- cold-miss tier: exact-only keying (pre-§9 behavior) ----------- #
    svc_cold = PlanService(cache_dir=tempfile.mkdtemp(),
                           tuner=TunerConfig(profile_bucket=None, **_SEARCH))
    cold = []
    for coo in patterns:
        us, _, st = _request_us(svc_cold, coo, x)
        if st.kind == "cold":    # two patterns may share an exact profile
            cold.append(us)

    # --- bucket-hit tier: log2 bucketing, one warm-up search ----------- #
    svc = PlanService(cache_dir=tempfile.mkdtemp(),
                      tuner=TunerConfig(profile_bucket="log2", **_SEARCH))
    _request_us(svc, _routing(N, E, k, C, 7), x)     # pays the one search
    bucket, outs = [], []
    for coo in patterns:
        us, out, st = _request_us(svc, coo, x)
        assert st.kind in ("bucket", "exact"), st.kind
        if st.kind == "bucket":
            bucket.append(us)
        outs.append(out)

    # --- exact-hit tier: the same pattern repeated --------------------- #
    exact = []
    for _ in range(stream):
        us, _, st = _request_us(svc, patterns[0], x)
        assert st.kind == "exact", st.kind
        exact.append(us)

    # --- 1e-5 parity: bucket-hit execution vs freshly tuned plans ------ #
    fresh = PlanService(cache_dir=tempfile.mkdtemp(),
                        tuner=TunerConfig(profile_bucket=None, **_SEARCH))
    for coo, out in zip(patterns[:4], outs[:4]):
        ref, _ = fresh.dispatch(coo, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    pct = lambda v: (float(np.percentile(v, 50)), float(np.percentile(v, 99)))
    p50c, p99c = pct(cold)
    p50b, p99b = pct(bucket)
    p50e, p99e = pct(exact)
    # the SLOs this PR ships (ISSUE 6 acceptance)
    assert p50b <= 5 * p50e, f"bucket p50 {p50b} > 5x exact p50 {p50e}"
    assert p50c >= 10 * p50b, f"cold p50 {p50c} < 10x bucket p50 {p50b}"
    assert p50c >= 10 * p50e, f"cold p50 {p50c} < 10x exact p50 {p50e}"

    rows = [("bench", "phase", "us_per_call", "p99_us", "n"),
            ("serve", "cold-miss", round(p50c, 1), round(p99c, 1), len(cold)),
            ("serve", "exact-hit", round(p50e, 1), round(p99e, 1), len(exact)),
            ("serve", "bucket-hit", round(p50b, 1), round(p99b, 1),
             len(bucket))]
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
