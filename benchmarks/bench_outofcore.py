"""Out-of-core replay (DESIGN.md §10, docs/out-of-core.md): the cost of
running a tuned plan under a memory budget vs running it unsliced.

Per kernel (MTTKRP and TTMc) the suite reports the unsliced schedule and
the same schedule replayed at budget = peak/2 and peak/4 — the slicing
overhead is the extra passes over the sparse operand, so the budgeted
rows bound what "tensor bigger than HBM" costs on this runtime.  Every
run asserts the out-of-core contract in-bench: each chunk's footprint
(tail included) prices at or under the budget, sliced results match
unsliced to 1e-4, and a budgeted tune leaves exactly ONE unsliced plan
in the cache (the decision never forks the cache key)."""
from __future__ import annotations

import glob
import json
import os
import tempfile

import numpy as np

import jax

from benchmarks.common import emit, timeit
from repro.autotune import TunerConfig, tune
from repro.core import spec as S
from repro.core.executor import CSFArrays, execute_plan, make_executor
from repro.core.planner import plan
from repro.core.slicing import (chunk_footprints, plan_peak_bytes,
                                sliced_execute, stamp_plan_slicing)
from repro.sparse import build_csf, random_sparse

_SEARCH = dict(max_paths=2, max_candidates=2, orders_per_path=1,
               warmup=1, repeats=2)


def _kernels(scale: float):
    s = lambda x: max(8, int(x * scale))
    I, J, K = s(256), s(192), s(128)
    coo = random_sparse((I, J, K), 2e-3, seed=7)
    csf = build_csf(coo)
    rng = np.random.default_rng(0)
    R = s(64)
    r2, r3 = s(48), s(24)
    yield ("mttkrp", S.mttkrp(I, J, K, R), csf, {
        "B": rng.standard_normal((J, R)).astype(np.float32),
        "C": rng.standard_normal((K, R)).astype(np.float32)})
    yield ("ttmc", S.ttmc3(I, J, K, r2, r3), csf, {
        "U": rng.standard_normal((J, r2)).astype(np.float32),
        "V": rng.standard_normal((K, r3)).astype(np.float32)})


def run(scale: float = 1.0):
    rows = [("bench", "tensor", "schedule", "us_per_call", "chunks")]
    for name, spec, csf, factors in _kernels(scale):
        levels = csf.nnz_levels()
        p = plan(spec, nnz_levels=levels)
        arrays = CSFArrays.from_csf(csf)
        ex = make_executor(spec, p.path, p.order)
        unsliced = jax.jit(lambda f: ex(arrays, f))
        t_full = timeit(unsliced, factors)
        ref = np.asarray(unsliced(factors))
        rows.append(("outofcore", name, "unsliced",
                     round(t_full * 1e6, 1), 1))

        peak = plan_peak_bytes(spec, p.path, p.order, levels)
        for frac, label in ((2, "budget-1/2"), (4, "budget-1/4")):
            budget = peak // frac
            stamped = stamp_plan_slicing(p, levels, budget)
            assert stamped.slice_chunks > 1, (name, label)
            # the contract, asserted where the numbers are produced:
            # every chunk (tail included) prices under the budget
            assert max(chunk_footprints(stamped, levels)) <= budget
            cache = {}   # chunk executors persist across timed calls
            fn = lambda f: sliced_execute(stamped, arrays, f,
                                          executor_cache=cache)
            t_sliced = timeit(fn, factors)
            out = np.asarray(fn(factors))
            tol = 1e-4 * max(1.0, float(np.abs(ref).max()))
            assert np.allclose(out, ref, atol=tol), (name, label)
            rows.append(("outofcore", name, label,
                         round(t_sliced * 1e6, 1), stamped.slice_chunks))

        # one cached plan across chunks: a budgeted measured search
        # persists exactly one entry, and it is the UNSLICED winner
        with tempfile.TemporaryDirectory() as d:
            tuned, _ = tune(spec, csf=csf, factors=factors, cache_dir=d,
                            tuner=TunerConfig(**_SEARCH),
                            memory_budget=peak // 2)
            entries = glob.glob(os.path.join(d, "plan-*.json"))
            assert len(entries) == 1, entries
            with open(entries[0]) as f:
                doc = json.load(f)["plan"]
            assert doc["slice_mode"] is None and doc["slice_chunks"] == 1
            out = np.asarray(execute_plan(tuned, arrays, factors))
            tol = 1e-4 * max(1.0, float(np.abs(ref).max()))
            assert np.allclose(out, ref, atol=tol), name
    emit(rows)
    return rows


if __name__ == "__main__":
    run(scale=float(os.environ.get("SCALE", "1.0")))
