#!/usr/bin/env python
"""Benchmark regression gate for the CI bench-smoke lane.

Compares a freshly measured medians file (benchmarks/run.py with
BENCH_JSON=...) against the committed baseline (BENCH_pr4.json, which
added the fused-vs-staged MTTKRP pallas rows as gated entries) and
fails when any shared row slowed down by more than ``--threshold``
(default 3x — generous on purpose: CI runners are shared machines, and
the gate's job is to catch order-of-magnitude schedule regressions, not
scheduling jitter).

Seeding rule: a missing or empty baseline passes — the first run of the
lane establishes the perf trajectory instead of blocking it.  Rows that
appear on only one side are reported but never fatal (benchmarks come
and go; renames shouldn't break the build); a row absent from the
baseline prints an explicit ``NEW (non-gating)`` line so log readers —
and the next PR description — don't have to re-derive the convention.

Medians note (``--min-runs``): runner variance on shared machines is
measured and LARGE (ROADMAP.md records tttc6|0.01 swinging 0.38s–1.6s
across identical runs).  Each row is the median of one run's repeats;
tightening ``--threshold`` below ~3x is only sound when every compared
row is a median of at least ``--min-runs`` independent runs.

Usage:
  python scripts/check_bench_regression.py BASELINE.json NEW.json \
      [--threshold 3.0] [--min-runs N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: expected a JSON object of suites")
    return doc


def compare(base: dict, new: dict, threshold: float) -> int:
    regressions, improved, checked = [], 0, 0
    for suite, rows in sorted(new.items()):
        base_rows = base.get(suite, {})
        for key, us in sorted(rows.items()):
            old = base_rows.get(key)
            if old is None:
                # the gate's seeding rule, stated where it applies: a row
                # with no baseline counterpart cannot regress — it gates
                # from the next baseline refresh onward
                print(f"  NEW (non-gating): {suite}/{key} = {us:.1f}us")
                continue
            checked += 1
            ratio = us / old if old > 0 else float("inf")
            if ratio > threshold:
                regressions.append((suite, key, old, us, ratio))
            elif ratio < 1.0:
                improved += 1
    gone = [(s, k) for s, rows in sorted(base.items())
            for k in sorted(rows) if k not in new.get(s, {})]
    for s, k in gone:
        print(f"  baseline row disappeared (unchecked): {s}/{k}")

    print(f"checked {checked} rows against baseline "
          f"({improved} faster, {len(regressions)} over {threshold:g}x)")
    for suite, key, old, us, ratio in regressions:
        print(f"REGRESSION {suite}/{key}: {old:.1f}us -> {us:.1f}us "
              f"({ratio:.2f}x > {threshold:g}x)")
    if regressions:
        # the one-line verdict CI surfaces: name every offending row so
        # the failure is actionable without scrolling the log
        rows = ", ".join(f"{s}/{k} ({r:.2f}x)"
                         for s, k, _, _, r in regressions)
        print(f"FAIL: {len(regressions)} benchmark regression(s) over "
              f"{threshold:g}x: {rows}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=3.0)
    ap.add_argument(
        "--min-runs", type=int, default=1,
        help="declared medians-of-N convention for these rows; thresholds "
             "under ~3x require N > 1 (see ROADMAP.md variance note)")
    args = ap.parse_args(argv)

    if args.min_runs > 1:
        print(f"medians note: rows declared as medians of >= "
              f"{args.min_runs} runs; threshold {args.threshold:g}x")
    elif args.threshold < 3.0:
        print(f"medians note: threshold {args.threshold:g}x is tighter "
              "than the 3x default but rows are single-run medians "
              "(--min-runs 1); expect variance-driven false alarms "
              "(ROADMAP.md: tttc6|0.01 swings 0.38s-1.6s)")

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; seeding run — pass")
        return 0
    base = load(args.baseline)
    if not base:
        print("empty baseline; seeding run — pass")
        return 0
    new = load(args.new)
    return compare(base, new, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
