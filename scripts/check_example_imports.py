#!/usr/bin/env python
"""Facade-import lint for ``examples/`` (CI docs lane).

The examples are the repo's copy-paste surface: they must spell imports
through the top-level facade (``from repro import plan, ...``), not
through the deep implementation modules — a deep path pasted from an
example outlives refactors the facade absorbs.  This script fails on
any import of the facade-covered implementation packages
(``repro.core``, ``repro.autotune``, ``repro.sparse``,
``repro.kernels``, ``repro.distributed``) inside ``examples/*.py``.

Application-layer packages with no facade coverage
(``repro.configs``, ``repro.models``, ``repro.serve``, ``repro.train``,
``repro.data`` — the LM-workload examples) stay importable directly.

Imports are read with ``ast`` (no example is executed), so the lint
runs before dependencies are installed.

Usage:
  python scripts/check_example_imports.py [root]    # default: repo root
"""
from __future__ import annotations

import ast
import os
import sys

# implementation packages the facade re-exports — deep imports of these
# in examples defeat the facade
FACADE_COVERED = ("repro.core", "repro.autotune", "repro.sparse",
                  "repro.kernels", "repro.distributed")


def deep_imports(path: str) -> list[tuple[int, str]]:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            names = [node.module]
        else:
            continue
        for name in names:
            if any(name == p or name.startswith(p + ".")
                   for p in FACADE_COVERED):
                hits.append((node.lineno, name))
    return hits


def main(argv: list[str]) -> int:
    root = os.path.abspath(argv[1]) if len(argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    ex_dir = os.path.join(root, "examples")
    files = sorted(f for f in os.listdir(ex_dir) if f.endswith(".py"))
    bad = 0
    for f in files:
        for lineno, name in deep_imports(os.path.join(ex_dir, f)):
            print(f"DEEP IMPORT examples/{f}:{lineno}: {name} — import "
                  "it from the `repro` facade instead")
            bad += 1
    print(f"checked {len(files)} examples/*.py for deep imports: "
          f"{bad} violation(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
