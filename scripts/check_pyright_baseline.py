#!/usr/bin/env python
"""Pyright ratchet for the CI static-analysis lane.

Runs ``pyright --outputjson`` (scoped by ``pyrightconfig.json`` to the
typed core: ``src/repro/core/`` + ``src/repro/analysis/`` + the stage-IR
modules ``src/repro/kernels/codegen/``, basic mode)
and compares per-rule error counts against the committed baseline
``pyright_baseline.json``.  The gate is a ratchet, not a cliff: a rule's
count may only stay or fall; any rise fails the lane with the offending
diagnostics printed.

Seeding semantics (mirrors check_bench_regression.py): a missing
baseline — or one with ``"seeded": false`` — reports counts and passes,
so enabling the lane never blocks on pre-existing debt.  Run with
``--update`` (ideally in an environment with pyright and the runtime
deps installed, so imports resolve) to write a seeded baseline and
start gating.  ``--update`` without pyright writes a *blind* seed
(empty counts, ``"pyright_version": null``) — legal because a rule
with no baseline entry is non-gating on its first appearance (the
bench convention: a new row reports, never gates); the first
pyright-equipped ``--update`` pins real counts and tightens the
ratchet.  Pyright absent on a plain run → pass with a note, keeping
local minimal environments green.

Usage:
  python scripts/check_pyright_baseline.py [--update] [--baseline PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "pyright_baseline.json")


def run_pyright() -> dict | None:
    exe = shutil.which("pyright")
    if exe is None:
        return None
    proc = subprocess.run([exe, "--outputjson"], cwd=REPO,
                          capture_output=True, text=True)
    # pyright exits 1 when it reports errors; the JSON is still complete
    try:
        return json.loads(proc.stdout)
    except json.JSONDecodeError:
        print("pyright produced no JSON:", file=sys.stderr)
        sys.stderr.write(proc.stdout[-2000:] + proc.stderr[-2000:])
        raise


def rule_counts(report: dict) -> dict[str, int]:
    counts: dict[str, int] = {}
    for d in report.get("generalDiagnostics", []):
        if d.get("severity") != "error":
            continue
        rule = d.get("rule", "unclassified")
        counts[rule] = counts.get(rule, 0) + 1
    return counts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="write a seeded baseline from this run's counts")
    args = ap.parse_args(argv)

    report = run_pyright()
    if report is None:
        if args.update:
            with open(args.baseline, "w") as f:
                json.dump({"seeded": True, "pyright_version": None,
                           "counts": {}}, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"pyright not installed; blind seed written to "
                  f"{args.baseline} — rules gate from their first "
                  f"pyright-equipped --update")
            return 0
        print("pyright not installed; static-type ratchet skipped — pass")
        return 0
    counts = rule_counts(report)
    version = report.get("version", "?")
    total = sum(counts.values())
    print(f"pyright {version}: {total} error(s) across "
          f"{len(counts)} rule(s) in the typed core")
    for rule, n in sorted(counts.items()):
        print(f"  {rule}: {n}")

    if args.update:
        with open(args.baseline, "w") as f:
            json.dump({"seeded": True, "pyright_version": version,
                       "counts": counts}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline written to {args.baseline} (seeded)")
        return 0

    if not os.path.exists(args.baseline):
        print("no committed baseline; seeding run — pass "
              "(run with --update to start gating)")
        return 0
    with open(args.baseline) as f:
        base = json.load(f)
    if not base.get("seeded", False):
        print("baseline present but unseeded; counts reported, not "
              "gated — pass (run with --update to start gating)")
        return 0

    base_counts = base.get("counts", {})
    # a rule with no baseline entry is non-gating on first appearance
    # (bench seeding rule) — report it, tell the operator to pin it
    new_rules = sorted(r for r in counts if r not in base_counts)
    if new_rules:
        print(f"new rule(s) not in baseline (non-gating on first "
              f"appearance; re-run --update with pyright to pin): "
              f"{new_rules}")
    regressed = {r: (base_counts[r], n) for r, n in counts.items()
                 if r in base_counts and n > base_counts[r]}
    for r, (old, new) in sorted(regressed.items()):
        print(f"RATCHET {r}: {old} -> {new}")
        for d in report.get("generalDiagnostics", []):
            if d.get("severity") == "error" and \
                    d.get("rule", "unclassified") == r:
                rng = d.get("range", {}).get("start", {})
                print(f"    {d.get('file')}:{rng.get('line', 0) + 1}: "
                      f"{d.get('message', '').splitlines()[0]}")
    improved = [r for r, n in base_counts.items()
                if counts.get(r, 0) < n]
    if improved:
        print(f"improved rules (re-run --update to tighten the ratchet): "
              f"{sorted(improved)}")
    if regressed:
        print(f"FAIL: {len(regressed)} rule(s) above baseline",
              file=sys.stderr)
        return 1
    print("check_pyright_baseline: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
