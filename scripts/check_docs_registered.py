#!/usr/bin/env python
"""Docs-registration lint for the CI docs lane.

Every markdown file under ``docs/`` must be registered in
``tests/test_docs.py``'s ``MARKDOWN_WITH_DOCTESTS`` list.  That list is
what makes a doc *gated*: its ``>>>`` examples execute in tier-1 and in
the CI docs lane, and the same test module drives the intra-repo link
checker (``scripts/check_doc_links.py``) over it.  A doc added without
registration would silently rot — its examples never run and a lost
example is never noticed — so this script fails the build instead.

The registry is read syntactically (no test imports needed), so the
lint runs before dependencies are installed.

Usage:
  python scripts/check_docs_registered.py [root]    # default: repo root
"""
from __future__ import annotations

import ast
import os
import sys

REGISTRY_FILE = os.path.join("tests", "test_docs.py")
REGISTRY_NAME = "MARKDOWN_WITH_DOCTESTS"


def registered_docs(root: str) -> list[str]:
    """Repo-relative paths listed in the doctest registry.

    The registry is parsed with ``ast`` rather than a regex so that a
    commented-out entry really counts as unregistered — the lint's whole
    job is to notice docs whose examples stopped running."""
    path = os.path.join(root, REGISTRY_FILE)
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == REGISTRY_NAME
                for t in node.targets):
            value = ast.literal_eval(node.value)
            if (not isinstance(value, list)
                    or not all(isinstance(x, str) for x in value)):
                raise ValueError(
                    f"{REGISTRY_NAME} must be a list of string paths")
            return value
    raise ValueError(f"{REGISTRY_FILE} lost its {REGISTRY_NAME} list")


def docs_on_disk(root: str) -> list[str]:
    docs_dir = os.path.join(root, "docs")
    if not os.path.isdir(docs_dir):
        return []
    return sorted(
        os.path.join("docs", f) for f in os.listdir(docs_dir)
        if f.endswith(".md"))


def main(argv: list[str]) -> int:
    root = os.path.abspath(argv[1]) if len(argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    registered = set(registered_docs(root))
    on_disk = docs_on_disk(root)
    missing = [d for d in on_disk if d not in registered]
    # a registered doc that no longer exists is equally a rot signal
    gone = [d for d in sorted(registered)
            if d.startswith("docs/")
            and not os.path.exists(os.path.join(root, d))]
    for d in missing:
        print(f"UNREGISTERED DOC {d}: add it to {REGISTRY_NAME} in "
              f"{REGISTRY_FILE} so its examples are gated")
    for d in gone:
        print(f"STALE REGISTRATION {d}: listed in {REGISTRY_NAME} but "
              "missing on disk")
    print(f"checked {len(on_disk)} docs/*.md against {REGISTRY_NAME}: "
          f"{len(missing)} unregistered, {len(gone)} stale")
    return 1 if (missing or gone) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
