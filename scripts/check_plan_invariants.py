#!/usr/bin/env python
"""CI lane: the static verifier agrees with the engines on every nest.

Two phases over the paper's kernels (mttkrp / ttmc3 / tttp3 / tttc6):

* **Parity** — enumerate contraction paths and valid loop orders and
  assert ``verify_plan`` accepts each one (the planner/engines accept
  exactly these); then execute a bounded sample on the ``xla``,
  ``pallas``, and ``pallas-gpu`` engines against the dense oracle, so
  "verifier-accepts" provably implies "engine-accepts *and computes
  the right answer*" on every registered target.

* **Mutation battery** — seeded illegal plans (permuted sparse levels,
  sparse slice modes, mis-blocked tiles, doctored plan JSON, malformed
  mesh context, ...), each of which must be rejected with its stable
  ``SPTTN-E*`` code.  A battery row failing means either an invariant
  regressed or a diagnostic code silently changed — both are breaking.

Exit status 0 iff every check passes.  Runtime is bounded by
``--exec-budget`` (engine executions are the only expensive part).
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import sys

import numpy as np

KERNELS = {
    # name -> constructor args chosen tiny: enumeration is exhaustive,
    # execution takes milliseconds, and every structural case (deep CSF,
    # same-sparsity output, 6-term network) still appears
    "mttkrp": ("mttkrp", (6, 5, 4, 3)),
    "ttmc3": ("ttmc3", (5, 4, 3, 3, 2)),
    "tttp3": ("tttp3", (5, 4, 3, 3)),
    "tttc6": ("tttc6", (3, 2)),
}


def _spec_for(name):
    from repro.core import spec as S
    ctor, args = KERNELS[name]
    return getattr(S, ctor)(*args)


def _factors_for(spec, rng):
    return {t.name: rng.standard_normal(
                [spec.dims[i] for i in t.indices]).astype(np.float32)
            for t in spec.inputs if not t.is_sparse}


def _operand_for(spec, rng_seed=0):
    from repro.sparse import build_csf, random_sparse
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    return build_csf(random_sparse(shape, 0.3, seed=rng_seed))


def check_parity(max_paths: int, max_orders: int, exec_budget: int,
                 fails: list) -> tuple[int, int]:
    """Verifier accepts every enumerated nest; a bounded sample executes
    correctly on both compiled engines."""
    from repro.analysis import verify_plan
    from repro.core.executor import (CSFArrays, dense_oracle, make_executor)
    from repro.core.loopnest import enumerate_orders
    from repro.core.paths import min_depth_paths
    rng = np.random.default_rng(0)
    verified = executed = 0
    for name in KERNELS:
        spec = _spec_for(name)
        csf = _operand_for(spec)
        arrays = CSFArrays.from_csf(csf)
        factors = _factors_for(spec, rng)
        oracle = np.asarray(dense_oracle(spec, csf, factors), dtype=np.float64)
        for path in itertools.islice(
                min_depth_paths(spec, max_paths=max_paths, slack=1),
                max_paths):
            for order in itertools.islice(
                    enumerate_orders(path, spec.sparse_indices), max_orders):
                rep = verify_plan(spec, path, order)
                verified += 1
                if not rep.ok:
                    fails.append(f"parity/{name}: verifier rejected an "
                                 f"enumerated nest: {rep.codes}")
                    continue
                if executed >= exec_budget:
                    continue
                for backend in ("xla", "pallas", "pallas-gpu"):
                    try:
                        ex = make_executor(spec, path, order,
                                           backend=backend, interpret=True)
                        out = np.asarray(ex(arrays, factors),
                                         dtype=np.float64)
                    except Exception as e:  # engine rejected a verified nest
                        fails.append(f"parity/{name}/{backend}: engine "
                                     f"raised on a verifier-accepted nest: "
                                     f"{e}")
                        continue
                    if not np.allclose(out, oracle, rtol=1e-3, atol=1e-3):
                        fails.append(f"parity/{name}/{backend}: wrong "
                                     f"answer on a verifier-accepted nest")
                executed += 1
    return verified, executed


def _swap_sparse(order, sparse):
    """Swap the first two sparse indices found in some term's order."""
    out = []
    done = False
    for a in order:
        sp = [i for i in a if i in sparse]
        if not done and len(sp) >= 2:
            b = list(a)
            i, j = b.index(sp[0]), b.index(sp[1])
            b[i], b[j] = b[j], b[i]
            out.append(tuple(b))
            done = True
        else:
            out.append(tuple(a))
    return tuple(out) if done else None


def check_battery(fails: list) -> int:
    """Every seeded illegal plan is rejected with its stable code."""
    from repro.analysis import verify_plan
    from repro.analysis.invariants import check_block_grid
    from repro.core.executor import plan_from_json, plan_to_json
    from repro.core.planner import plan as make_plan

    p = make_plan(_spec_for("mttkrp"))
    spec = p.spec
    p_sp = make_plan(_spec_for("tttp3"))     # same-sparsity output, no chain

    swapped = _swap_sparse(p.order, set(spec.sparse_indices))
    cases = [
        ("permuted-levels", "SPTTN-E001",
         lambda: verify_plan(spec, p.path, swapped)),
        ("not-a-permutation", "SPTTN-E002",
         lambda: verify_plan(spec, p.path,
                             (p.order[0][:-1],) + p.order[1:])),
        ("order-length", "SPTTN-E003",
         lambda: verify_plan(spec, p.path, p.order[:-1])),
        ("wrong-final-output", "SPTTN-E004",
         lambda: verify_plan(spec, p.path[:-1], p.order[:-1])),
        ("fused-without-chain", "SPTTN-E010",
         lambda: verify_plan(p_sp, fused=True)),
        ("block-not-positive", "SPTTN-E020",
         lambda: verify_plan(dataclasses.replace(p, block=0))),
        ("block-misaligned", "SPTTN-E021",
         lambda: verify_plan(dataclasses.replace(p, block=100))),
        ("slice-unknown-mode", "SPTTN-E030",
         lambda: verify_plan(dataclasses.replace(
             p, slice_mode="q", slice_chunks=2))),
        ("slice-sparse-mode", "SPTTN-E031",
         lambda: verify_plan(dataclasses.replace(
             p, slice_mode=spec.sparse_indices[0], slice_chunks=2))),
        ("slice-chunks-range", "SPTTN-E032",
         lambda: verify_plan(dataclasses.replace(
             p, slice_mode="a", slice_chunks=10**6))),
        ("slice-chunks-no-mode", "SPTTN-E033",
         lambda: verify_plan(dataclasses.replace(p, slice_chunks=4))),
        ("unknown-backend", "SPTTN-E040",
         lambda: verify_plan(p, backend="tpu")),
        ("mesh-malformed", "SPTTN-E050",
         lambda: verify_plan(dataclasses.replace(
             p, mesh={"mesh_shape": 3}))),
        ("sparse-output-stacked", "SPTTN-E052",
         lambda: verify_plan(p_sp, stacked=True)),
    ]
    ran = 0
    for label, code, run in cases:
        rep = run()
        ran += 1
        if code not in rep.codes or rep.ok:
            fails.append(f"battery/{label}: expected {code}, got "
                         f"{rep.codes} (ok={rep.ok})")

    # mis-blocked tiles: the stage-grid invariant directly
    d = check_block_grid(130, 128)
    ran += 1
    if d is None or d.code != "SPTTN-E022":
        fails.append(f"battery/block-grid: expected SPTTN-E022, got {d}")

    # backend whose stage lowering is unregistered on this host (E041):
    # pop the gpu target from the registry, verify, restore
    from repro.kernels.codegen import ir as codegen_ir
    p_gpu = dataclasses.replace(p, backend="pallas-gpu")
    saved = codegen_ir._LOWERINGS.pop("gpu")
    try:
        rep = verify_plan(p_gpu)
        ran += 1
        if "SPTTN-E041" not in rep.codes or rep.ok:
            fails.append(f"battery/unregistered-lowering: expected "
                         f"SPTTN-E041, got {rep.codes} (ok={rep.ok})")
    finally:
        codegen_ir._LOWERINGS["gpu"] = saved

    # device-kind mismatch is a warning, never a block (W005)
    rep = verify_plan(p_gpu, device_kind="tpu")
    ran += 1
    if "SPTTN-W005" not in rep.codes or not rep.ok:
        fails.append(f"battery/device-kind: expected non-blocking "
                     f"SPTTN-W005, got {rep.codes} (ok={rep.ok})")

    # broadcast-down lift: a doctored path whose second stage consumes a
    # level-1 FiberVal at level 2 with storage-prefix intact — no
    # same-level zero operand, so the stacked engine's zero-on-pads
    # induction fails (no enumerable paper path trips this: the
    # induction holds on all of them, which is why the stacked engine
    # covers them — the battery must doctor one)
    from repro.core.paths import Operand, Term
    S = Operand(spec.sparse_input.name, ("i", "j", "k"), is_sparse=True)
    B, C = Operand("B", ("j", "a")), Operand("C", ("k", "a"))
    t0 = Operand("t0", ("i", "a"))
    bad_path = (Term(lhs=S, rhs=C, out=t0),
                Term(lhs=t0, rhs=B, out=Operand("OUT", ("i", "a"))))
    bad_order = (("i", "j", "k", "a"), ("i", "j", "a"))
    from repro.analysis.invariants import stackable_diagnostics
    sd = stackable_diagnostics(spec, bad_path)
    ran += 1
    if [x.code for x in sd] != ["SPTTN-E051"]:
        fails.append(f"battery/not-stackable: expected SPTTN-E051, got "
                     f"{[x.code for x in sd]}")
    rep = verify_plan(spec, bad_path, bad_order, stacked=True)
    ran += 1
    if "SPTTN-E051" not in rep.codes:
        fails.append(f"battery/not-stackable-verify: expected SPTTN-E051 "
                     f"in {rep.codes}")

    # doctored plan JSON: the load path must refuse with the same codes
    doc_cases = [
        ("json-version", {"version": 5}, "SPTTN-E060"),
        ("json-block", {"block": 100}, "SPTTN-E021"),
        ("json-slice-sparse",
         {"slice_mode": spec.sparse_indices[0], "slice_chunks": 2},
         "SPTTN-E031"),
        ("json-backend", {"backend": "tpu"}, "SPTTN-E040"),
        ("json-mesh", {"mesh": {"mesh_shape": 3}}, "SPTTN-E050"),
    ]
    for label, patch, code in doc_cases:
        doc = json.loads(plan_to_json(p))
        doc.update(patch)
        ran += 1
        try:
            plan_from_json(json.dumps(doc))
        except ValueError as e:
            if code not in str(e):
                fails.append(f"battery/{label}: rejected without {code}: "
                             f"{e}")
        else:
            fails.append(f"battery/{label}: doctored doc was accepted")

    # pre-flight: execute_plan refuses a doctored in-memory plan before
    # any engine is built
    from repro.analysis import PlanVerificationError
    from repro.core.executor import CSFArrays, execute_plan
    csf = _operand_for(p_sp.spec)
    rng = np.random.default_rng(1)
    ran += 1
    try:
        execute_plan(dataclasses.replace(p_sp, fused=True),
                     CSFArrays.from_csf(csf), _factors_for(p_sp.spec, rng))
    except PlanVerificationError as e:
        if "SPTTN-E010" not in str(e):
            fails.append(f"battery/preflight: missing SPTTN-E010: {e}")
    else:
        fails.append("battery/preflight: execute_plan ran a doctored plan")
    return ran


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--max-paths", type=int, default=6,
                    help="paths enumerated per kernel (min-depth first)")
    ap.add_argument("--max-orders", type=int, default=4,
                    help="valid loop orders verified per path")
    ap.add_argument("--exec-budget", type=int, default=10,
                    help="nests executed on both engines vs the oracle")
    args = ap.parse_args(argv)

    fails: list[str] = []
    verified, executed = check_parity(args.max_paths, args.max_orders,
                                      args.exec_budget, fails)
    ran = check_battery(fails)

    print(f"parity: {verified} nests verified, {executed} executed on "
          f"xla+pallas+pallas-gpu vs the dense oracle")
    print(f"battery: {ran} seeded plans, each required to produce "
          f"its stable SPTTN-E*/W* code")
    for f in fails:
        print(f"FAIL {f}")
    print("check_plan_invariants:", "FAIL" if fails else "OK")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
