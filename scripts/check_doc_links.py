#!/usr/bin/env python
"""Intra-repo link checker for the CI docs lane.

Scans every tracked markdown file for inline links/images
(``[text](target)``) and fails when a *relative* target does not exist on
disk, resolved against the file that contains it.  External schemes
(http/https/mailto) and pure in-page anchors (``#section``) are skipped —
this gate is about repo-internal rot, not the internet.  ``path#anchor``
targets are checked for the file part only.

Usage:
  python scripts/check_doc_links.py [root]      # default: repo root
"""
from __future__ import annotations

import os
import re
import sys

# inline markdown link/image: [text](target) / ![alt](target); stops at
# the first unescaped ')' so titles ("...") are carried and stripped below
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache", "node_modules"}


def md_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


def broken_links(path: str, root: str) -> list[tuple[int, str]]:
    bad = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, start=1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                    continue
                file_part = target.split("#", 1)[0]
                if not file_part:
                    continue
                if file_part.startswith("/"):
                    resolved = os.path.join(root, file_part.lstrip("/"))
                else:
                    resolved = os.path.join(os.path.dirname(path), file_part)
                if not os.path.exists(resolved):
                    bad.append((lineno, target))
    return bad


def main(argv: list[str]) -> int:
    root = os.path.abspath(argv[1]) if len(argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    files = md_files(root)
    failures = 0
    for path in files:
        for lineno, target in broken_links(path, root):
            rel = os.path.relpath(path, root)
            print(f"BROKEN LINK {rel}:{lineno}: ({target})")
            failures += 1
    print(f"checked {len(files)} markdown files: "
          f"{failures} broken intra-repo link(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
