"""Hypothesis properties of the stacked shard padding (DESIGN.md §7).

Host-side only — ``partition_mesh`` reads nothing from the mesh but its
axis sizes, so a plain namespace stands in and no fake devices are
needed.  Properties: (1) ``unpad_local_csf`` inverts ``_pad_local_csf``
bit-exactly for every shard (padding is strictly appended, never mixed
into real slots); (2) each stacked row IS the shard's padded CSF and its
segment tails stay sorted ascending — the precondition of both the
Pallas block layouts (``padded_segment_layout``) and
``segment_sum(indices_are_sorted=True)``.

Skipped wholesale where hypothesis is not installed (the CI full lane
has it; minimal local envs may not).
"""
import types

import numpy as np
import pytest

from repro.core import spec as S
from repro.distributed import unpad_local_csf
from repro.distributed.spttn_dist import _pad_local_csf, partition_mesh
from repro.sparse import random_sparse
from repro.sparse.csf import level_segments

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


def _fake_mesh(n):
    return types.SimpleNamespace(shape={"data": n})


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), nshards=st.sampled_from([1, 2, 3, 4]),
       density=st.floats(0.02, 0.4))
def test_pad_unpad_round_trip(seed, nshards, density):
    spec = S.mttkrp(13, 9, 7, 4)
    T = random_sparse((13, 9, 7), density, seed=seed)
    if T.nnz == 0:
        return
    part = partition_mesh(spec, T, _fake_mesh(nshards), {0: "data"})
    for s, csf in enumerate(part.csfs):
        back = unpad_local_csf(part.packed[s], csf.order, csf.nnz, csf.nfib)
        np.testing.assert_array_equal(back["values"], csf.values)
        for p in range(1, csf.order + 1):
            fc = csf.fiber_coords(p)
            for m in range(p):
                np.testing.assert_array_equal(back[f"coord_{p}_{m}"],
                                              fc[:, m])
        for child in range(1, csf.order + 1):
            for par in range(0, child):
                np.testing.assert_array_equal(
                    back[f"seg_{child}_{par}"],
                    level_segments(csf, child, par))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), nshards=st.sampled_from([1, 2, 4]),
       density=st.floats(0.02, 0.4))
def test_stacked_layout_agrees_with_per_shard_csf(seed, nshards, density):
    spec = S.mttkrp(13, 9, 7, 4)
    T = random_sparse((13, 9, 7), density, seed=seed)
    if T.nnz == 0:
        return
    part = partition_mesh(spec, T, _fake_mesh(nshards), {0: "data"})
    stacked = {k: np.asarray(v) for k, v in part.stacked.items()}
    total = 0
    for s, csf in enumerate(part.csfs):
        row = {k: stacked[k][s] for k in stacked}
        fresh = _pad_local_csf(csf, part.max_nnz, part.max_nfib)
        for k in fresh:
            np.testing.assert_array_equal(row[k], fresh[k])
        for child in range(1, csf.order + 1):
            for par in range(1, child):
                seg = row[f"seg_{child}_{par}"]
                assert (np.diff(seg) >= 0).all(), (s, child, par, seg)
        total += csf.nnz
    assert total == T.nnz        # partition is a disjoint cover
