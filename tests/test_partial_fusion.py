"""Partially-fused loop nests (paper §8 future work, implemented at the
enumeration/cost level)."""

from repro.core import spec as S
from repro.core.loopnest import build_forest
from repro.core.partial_fusion import (best_partial_fusion,
                                       build_forest_with_barriers,
                                       enumerate_barrier_choices,
                                       partial_fusion_metrics)
from repro.core.paths import min_depth_paths


def _ttmc_tv_path(spec):
    return next(p for p in min_depth_paths(spec)
                if "(T.V)" in p[0].out.name)


def test_no_barriers_is_fully_fused():
    spec = S.ttmc3(8, 8, 8, 4, 4)
    _ttmc_tv_path(spec)      # raises if the T.V-first path disappears
    order = (("i", "j", "k", "s"), ("i", "j", "s", "r"))
    f1 = build_forest(order)
    f2 = build_forest_with_barriers(order, (False,))
    # identical structure: fused under (i, j)
    assert len(f1) == len(f2) == 1


def test_full_barriers_reproduce_pairwise_listing2():
    """All-barriers == the paper's Listing 2 (independent loop nests):
    the intermediate X(i,j,s) is fully buffered (dim 3)."""
    spec = S.ttmc3(8, 8, 8, 4, 4)
    path = _ttmc_tv_path(spec)
    order = (("i", "j", "k", "s"), ("i", "j", "s", "r"))
    fused = partial_fusion_metrics(path, order, (False,), spec.dims,
                                   spec.sparse_indices)
    unfused = partial_fusion_metrics(path, order, (True,), spec.dims,
                                     spec.sparse_indices)
    assert fused["max_buffer_dim"] == 1       # X[s] vector (Listing 3)
    assert unfused["max_buffer_dim"] == 3     # X[i,j,s]    (Listing 2)
    assert unfused["n_roots"] == 2 and fused["n_roots"] == 1


def test_partial_fusion_can_buy_blas_loops():
    """TTTP: barriers around the dense (U.V) term free its loops from the
    sparse prefix, increasing the total BLAS-able loop count at a buffer
    cost — exactly the trade the paper's future-work section names."""
    spec = S.tttp3(8, 8, 8, 4)
    path = next(p for p in min_depth_paths(spec)
                if "(U.V)" in p[1].out.name)
    order = (("i", "j", "k", "r"), ("i", "j", "r"), ("i", "j", "k", "r"))
    base = partial_fusion_metrics(path, order, (False, False), spec.dims,
                                  spec.sparse_indices)
    b, best = best_partial_fusion(path, order, spec.dims,
                                  spec.sparse_indices)
    assert best["blas_loops"] >= base["blas_loops"]
    # and constrained search respects the bound
    b2, m2 = best_partial_fusion(path, order, spec.dims,
                                 spec.sparse_indices, buffer_dim_bound=3)
    assert m2["max_buffer_dim"] <= 3


def test_barrier_enumeration_size():
    assert len(list(enumerate_barrier_choices(4))) == 8
    assert list(enumerate_barrier_choices(1)) == [()]
