"""Multi-device tests (subprocess with 8 fake CPU devices): distributed
SpTTN == single-device oracle (paper §5.2), compressed psum unbiasedness,
sharding-rule consistency, small-mesh train-step lowering."""
import numpy as np
import pytest

from tests.conftest import run_with_devices


def test_distributed_spttn_matches_oracle():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import spec as S
from repro.core.planner import plan
from repro.core.executor import dense_oracle
from repro.distributed.spttn_dist import make_distributed, undo_cyclic
from repro.sparse import build_csf, random_sparse

mesh = jax.make_mesh((4, 2), ("data", "model"))
spec = S.mttkrp(16, 12, 10, 8)
T = random_sparse((16, 12, 10), 0.1, seed=2)
csf = build_csf(T)
rng = np.random.default_rng(0)
factors = {"B": jnp.asarray(rng.standard_normal((12, 8)).astype(np.float32)),
           "C": jnp.asarray(rng.standard_normal((10, 8)).astype(np.float32))}
pl = plan(spec, nnz_levels=csf.nnz_levels())
dist = make_distributed(spec, pl, T, mesh, mode_axis={0: "data"})
out = np.asarray(dist(factors))
oracle = dense_oracle(spec, csf, {k: np.asarray(v) for k, v in factors.items()})
out = undo_cyclic(out, spec, {0: "data"}, mesh, T.shape)[:16]
np.testing.assert_allclose(out, oracle, atol=1e-3)
print("SPTTN-DIST-OK")

# 2-D grid: modes 0 and 1 partitioned; mode-1 (j) is contracted => psum
dist2 = make_distributed(spec, pl, T, mesh, mode_axis={0: "data", 1: "model"})
out2 = np.asarray(dist2(factors))
out2 = undo_cyclic(out2, spec, {0: "data", 1: "model"}, mesh, T.shape)[:16]
np.testing.assert_allclose(out2, oracle, atol=1e-3)
print("SPTTN-DIST-2D-OK")
"""
    out = run_with_devices(code, 8)
    assert "SPTTN-DIST-OK" in out and "SPTTN-DIST-2D-OK" in out


def test_compressed_psum_unbiased():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import compressed_psum, shard_map

mesh = jax.make_mesh((8,), ("d",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 3.0

def f(xs, key):
    return compressed_psum(xs, "d", key)

g = jax.jit(shard_map(f, mesh=mesh,
    in_specs=(P("d"), P()), out_specs=P("d"), check_vma=False))
exact = np.asarray(x).sum(0)
outs = []
for s in range(20):
    key = jax.random.PRNGKey(s)
    r = np.asarray(g(x, key))
    outs.append(r[0])   # every shard returns the same psum
err_mean = np.abs(np.mean(outs, 0) - exact).max()
scale = np.abs(exact).max()
assert err_mean < 0.05 * scale + 0.05, (err_mean, scale)
print("PSUM-OK", err_mean)
"""
    out = run_with_devices(code, 8)
    assert "PSUM-OK" in out


def test_reduce_scatter_grads():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import reduce_scatter_grads, shard_map

mesh = jax.make_mesh((8,), ("d",))
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16, 4)),
     "b": jax.random.normal(jax.random.PRNGKey(1), (8, 3))}

def f(grads):
    local = jax.tree.map(lambda x: x[0], grads)
    return reduce_scatter_grads(local, "d")

fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("d"),),
                       out_specs={"w": P("d"), "b": P()},
                       check_vma=False))
out = fn(g)
np.testing.assert_allclose(np.asarray(out["w"]),
                           np.asarray(g["w"]).sum(0), atol=1e-5)
np.testing.assert_allclose(np.asarray(out["b"])[:3],
                           np.asarray(g["b"]).sum(0), atol=1e-5)
print("RS-OK")
"""
    out = run_with_devices(code, 8)
    assert "RS-OK" in out


@pytest.mark.slow
def test_sharded_train_step_runs():
    """Real sharded train step on a (4,2) mesh with a reduced model:
    loss finite + params sharded as specified."""
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from repro.configs import get_reduced, make_batch
from repro.configs.base import RunConfig
from repro.distributed import sharding as SH
from repro.models import model_init
from repro.train.train_step import init_train_state, make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("granite-moe-1b-a400m")
params, specs = model_init(jax.random.PRNGKey(0), cfg)
rules = SH.default_rules(False, "train")
psh = SH.tree_sharding(params, specs, rules, mesh)
params = jax.device_put(params, psh)
state = init_train_state(params)
batch = make_batch(cfg, "train_4k", batch_override=8, seq_override=32)
batch = jax.device_put(batch, jax.tree.map(
    lambda _: SH.NamedSharding(mesh, SH.P("data")), batch))
run = RunConfig(model=cfg, remat=True)
with SH.mesh_context(mesh, rules):
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    state2, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
print("SHARDED-TRAIN-OK", float(m["loss"]))
"""
    out = run_with_devices(code, 8)
    assert "SHARDED-TRAIN-OK" in out


def test_tree_sharding_rules():
    code = """
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed import sharding as SH

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = SH.default_rules(False, "train")
shapes = {"w": jax.ShapeDtypeStruct((32, 8), jnp.float32),
          "e": jax.ShapeDtypeStruct((6, 32, 8), jnp.float32),
          "tiny": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
specs = {"w": ("embed", "ffn"), "e": ("experts", "embed", "ffn"),
         "tiny": ("embed", "ffn")}
sh = SH.tree_sharding(shapes, specs, rules, mesh)
assert sh["w"].spec == P(("data",), "model"), sh["w"].spec
# experts=6 not divisible by model=2? 6 % 2 == 0 -> sharded; ffn blocked (dup)
assert sh["e"].spec == P("model", ("data",), None), sh["e"].spec
# indivisible dims are replicated, never error
assert sh["tiny"].spec == P(None, None), sh["tiny"].spec
print("RULES-OK")
"""
    out = run_with_devices(code, 8)
    assert "RULES-OK" in out
