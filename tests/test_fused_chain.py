"""Single-kernel multi-level fused codegen (DESIGN.md §6, ISSUE 4).

Covers: (a) a chain of reducing terms sharing the sparse operand's CSF
path (MTTKRP's leaf→2 then 2→1) executes as ONE ``pallas_call`` — one
``stage_strategy`` entry for the whole chain — with 1e-5 parity against
``reference_execute``; (b) chain detection accepts exactly the provably
safe shapes (consecutive consumers, dense-factor links, strictly
descending levels) and declines the rest; (c) fused/staged is an
autotuning axis whose winner persists through plan JSON v4 (v3
rejected) and replays through ``execute_plan``; (d) the satellite
bugfixes — stage accumulator dtype derived from the operands (float64
never silently truncated to float32), pruned measurements never winning
the search, and the plan cache rejecting stale-but-parseable entries by
explicit version guard."""
import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.autotune import TunerConfig, generate_candidates, tune
from repro.autotune.cache import CACHE_VERSION, PlanCache
from repro.autotune.candidates import Candidate
from repro.autotune.measure import Measurement
from repro.core import spec as S
from repro.core.executor import (CSFArrays, dense_oracle, execute_plan,
                                 plan_from_dict, plan_from_json,
                                 plan_to_dict, plan_to_json,
                                 reference_execute)
from repro.core.planner import plan
from repro.kernels.codegen import (PallasPlanExecutor, accumulator_type,
                                   fusible_chains)
from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import from_coords


def _factors(spec, rng, dtype=np.float32):
    return {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(dtype)
        for t in spec.inputs if not t.is_sparse}


# --------------------------------------------------------------------- #
# (a) one kernel for the whole chain, exact semantics
# --------------------------------------------------------------------- #
def test_mttkrp_chain_runs_as_single_kernel():
    """Acceptance bar: MTTKRP's two reducing terms execute as a single
    pallas_call — the stage-strategy record holds exactly one entry, the
    fused chain's (leaf level, final out level) — with 1e-5 parity."""
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(random_sparse((6, 7, 8), 0.3, seed=3))
    rng = np.random.default_rng(1)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())

    chains = fusible_chains(spec, p.path)
    assert chains == {0: (0, 1)}          # leaf->2 feeding 2->1

    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy="fused")
    out = np.asarray(ex(arrays, factors))
    # ONE kernel launch for both reducing terms: a single strategy entry
    # keyed by the chain's (innermost lvl, final out_lvl), marked fused
    assert ex.stage_strategy == {(3, 1): "fused"}
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    np.testing.assert_allclose(out, ref, atol=1e-5)
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-5)

    # the staged lowering of the same plan launches one kernel per term
    staged = PallasPlanExecutor(spec, p.path, p.order, block=8,
                                interpret=True, strategy="auto")
    np.testing.assert_allclose(np.asarray(staged(arrays, factors)), ref,
                               atol=1e-5)
    assert len(staged.stage_strategy) == 2
    assert set(staged.stage_strategy) == {(3, 2), (2, 1)}


def test_three_level_chain_single_kernel():
    """Order-4 TTMc chains leaf→1 through two intermediate levels: two
    VMEM scratch buffers, still one kernel."""
    spec = S.ttmc4(4, 5, 6, 7, 3, 2, 2)
    csf = build_csf(random_sparse((4, 5, 6, 7), 0.2, seed=5))
    rng = np.random.default_rng(2)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    chains = fusible_chains(spec, p.path)
    assert any(len(tids) == 3 for tids in chains.values())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy="fused")
    out = np.asarray(ex(arrays, factors))
    assert list(ex.stage_strategy.values()).count("fused") == 1
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_fused_chain_under_jit_and_blocks():
    spec = S.mttkrp(12, 10, 8, 5)
    csf = build_csf(random_sparse((12, 10, 8), 0.15, seed=9))
    rng = np.random.default_rng(3)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, p.path, p.order, csf,
                            {k: np.asarray(v) for k, v in factors.items()})
    for block in (4, 8, 16):
        ex = PallasPlanExecutor(spec, p.path, p.order, block=block,
                                interpret=True, strategy="fused")
        fn = jax.jit(lambda f, ex=ex: ex(arrays, f))
        np.testing.assert_allclose(np.asarray(fn(factors)), ref, atol=1e-5,
                                   err_msg=f"block={block}")
        np.testing.assert_allclose(np.asarray(fn(factors)),
                                   np.asarray(fn(factors)))


# --------------------------------------------------------------------- #
# (b) chain detection: what fuses and what declines
# --------------------------------------------------------------------- #
def test_chain_detection_declines_unsafe_shapes():
    # TTTP: the final term keeps the leaf level (product, not reducing)
    spec = S.tttp3(6, 7, 8, 4)
    p = plan(spec)
    assert fusible_chains(spec, p.path) == {}
    # SDDMM: a single reducing term — nothing to chain
    spec = S.sddmm(6, 7, 4)
    p = plan(spec)
    assert fusible_chains(spec, p.path) == {}
    # non-consecutive consumer: (B.C) dense pre-contraction first, then
    # one sparse term — no reducing chain of length >= 2
    spec = S.mttkrp(6, 7, 8, 4)
    from repro.core.paths import enumerate_paths
    for path in enumerate_paths(spec):
        names = [t.lhs.name + "." + t.rhs.name for t in path]
        if names[0] == "B.C":
            assert fusible_chains(spec, path) == {}
            break
    else:                                   # pragma: no cover
        pytest.fail("no B.C-first path enumerated")


def test_fused_strategy_falls_back_on_declined_plans():
    """strategy='fused' on a plan with no fusible chain must execute the
    staged path unchanged (no fused entries, correct result)."""
    spec = S.tttp3(6, 7, 8, 4)
    csf = build_csf(random_sparse((6, 7, 8), 0.3, seed=3))
    rng = np.random.default_rng(4)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy="fused")
    out = np.asarray(ex(arrays, factors))
    assert "fused" not in ex.stage_strategy.values()
    dense = np.zeros([spec.dims[i] for i in spec.output.indices])
    dense[tuple(csf.coo.coords.T)] = out
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    np.testing.assert_allclose(dense, ref, atol=1e-5)


def test_unknown_strategy_still_rejected():
    spec = S.mttkrp(6, 7, 8, 4)
    p = plan(spec)
    with pytest.raises(ValueError, match="unknown strategy"):
        PallasPlanExecutor(spec, p.path, p.order, strategy="unfused")


# --------------------------------------------------------------------- #
# (c) fusion as an autotuning axis + plan JSON v4
# --------------------------------------------------------------------- #
def test_candidates_expand_fusion_axis_for_pallas_only():
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    cands = generate_candidates(spec, nnz_levels=csf.nnz_levels(),
                                max_paths=2, max_candidates=3,
                                orders_per_path=1,
                                backends=("xla", "pallas"))
    assert len({c.key for c in cands}) == len(cands)
    assert not any(c.fused for c in cands if c.backend == "xla")
    pall = [c for c in cands if c.backend == "pallas"]
    chained = [c for c in pall if fusible_chains(spec, c.path)]
    assert chained and any(c.fused for c in chained)
    # every fusible pallas schedule is measured both ways
    for c in chained:
        twin = dataclasses.replace(c, fused=not c.fused)
        assert twin.key in {x.key for x in chained}


def test_fused_winner_persists_and_replays(tmp_path):
    """Force the fused lowering to win (it is the only candidate), then
    check JSON v4 round-trip, cache hit, and execute_plan routing."""
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    rng = np.random.default_rng(0)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    forced = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                         warmup=1, repeats=2, backends=("pallas",))
    tuned, stats = tune(spec, csf=csf, factors=factors,
                        cache_dir=str(tmp_path), tuner=forced)
    assert tuned.backend == "pallas"
    assert stats.candidates_timed == 2      # staged + fused, both measured

    fused_plan = dataclasses.replace(tuned, fused=True)
    doc = plan_to_dict(fused_plan)
    assert doc["version"] == 6 and doc["fused"] is True
    rt = plan_from_json(plan_to_json(fused_plan))
    assert rt == fused_plan and rt.fused

    # execute_plan routes a fused plan through the chain lowering
    out = execute_plan(fused_plan, CSFArrays.from_csf(csf), factors,
                       block=8, interpret=True)
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-4)

    # second search is a cache hit returning the same (possibly fused)
    # winner — the fusion flag survives the disk round trip
    tuned2, stats2 = tune(spec, csf=csf, factors=factors,
                          cache_dir=str(tmp_path), tuner=forced)
    assert stats2.cache_hit and tuned2 == tuned
    assert tuned2.fused == tuned.fused


def test_plan_json_v3_rejected():
    doc = plan_to_dict(plan(S.mttkrp(8, 6, 5, 3)))
    with pytest.raises(ValueError, match="unsupported plan version"):
        plan_from_dict(dict(doc, version=3))


# --------------------------------------------------------------------- #
# (d1) satellite: accumulator dtype derived from the stage dtype
# --------------------------------------------------------------------- #
@pytest.fixture
def x64():
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", False)


def test_accumulator_type_widens_never_narrows():
    assert accumulator_type(jnp.float32) == jnp.float32
    assert accumulator_type(jnp.bfloat16) == jnp.float32
    assert accumulator_type(np.float64) == np.float64


@pytest.mark.parametrize("strategy", ["row", "segsum", "fused"])
def test_float64_operands_accumulate_at_float64(x64, strategy):
    """Regression: the stage einsums hard-coded
    preferred_element_type=float32, so float64 operands silently lost
    half their mantissa.  With the accumulator derived from the stage
    dtype the generated kernels must match the float64 numpy oracle to
    machine precision — a float32 accumulation would sit at ~1e-7."""
    spec = S.mttkrp(10, 8, 6, 4)
    coo = random_sparse((10, 8, 6), 0.25, seed=7)
    coo = from_coords(coo.coords, coo.values.astype(np.float64), coo.shape)
    csf = build_csf(coo)
    rng = np.random.default_rng(2)
    factors = _factors(spec, rng, dtype=np.float64)
    arrays = CSFArrays.from_csf(csf)
    assert arrays.values.dtype == jnp.float64
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy=strategy)
    out = np.asarray(ex(arrays, factors))
    assert out.dtype == np.float64
    oracle = dense_oracle(spec, csf, factors)
    np.testing.assert_allclose(out, oracle, atol=1e-12, rtol=1e-12)


# --------------------------------------------------------------------- #
# (d2) satellite: pruned measurements never win the search
# --------------------------------------------------------------------- #
def test_pruned_candidate_never_wins(monkeypatch):
    """Regression: measure_candidates used to sort pruned single-sample
    entries into the same list as full medians; a pruned entry tying the
    best median could be returned as the winner.  The tuner must skip
    pruned entries explicitly and account for them in SearchStats."""
    import repro.autotune.tuner as tuner_mod
    spec = S.mttkrp(8, 6, 5, 3)
    csf = build_csf(random_sparse((8, 6, 5), 0.2, seed=1))

    captured = {}

    def fake_measure(spec_, candidates, arrays, factors, config=None,
                     stats=None):
        full = Candidate(path=candidates[0].path, order=candidates[0].order,
                         cost=0.0, flops=0.0, backend="xla")
        pruned = Candidate(path=candidates[-1].path,
                           order=candidates[-1].order,
                           cost=1.0, flops=1.0, backend="xla")
        if stats is not None:
            stats.candidates_timed = 2
            stats.pruned = 1
        captured["full"] = full
        # the pruned single-sample entry TIES the best median — under the
        # old ascending-seconds sort it came first and won the search
        return [Measurement(pruned, 1e-3, pruned=True),
                Measurement(full, 1e-3)]

    monkeypatch.setattr(tuner_mod, "measure_candidates", fake_measure)
    tuned, stats = tune(spec, csf=csf,
                        tuner=TunerConfig(max_paths=2, max_candidates=2,
                                           orders_per_path=1))
    assert (tuned.path, tuned.order) == (captured["full"].path,
                                         captured["full"].order)
    assert stats.pruned == 1
    assert stats.best_seconds == 1e-3


def test_measure_sorts_pruned_last_and_counts_them():
    """With a sub-1 prune ratio every candidate after the first is
    abandoned on its first call (first > ratio*best always holds), so
    the fully-measured head candidate must come out first regardless of
    the pruned entries' single-sample times."""
    from repro.autotune.measure import MeasureConfig, measure_candidates
    from repro.autotune.tuner import SearchStats
    spec = S.mttkrp(8, 6, 5, 3)
    csf = build_csf(random_sparse((8, 6, 5), 0.2, seed=1))
    arrays = CSFArrays.from_csf(csf)
    rng = np.random.default_rng(0)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    cands = generate_candidates(spec, nnz_levels=csf.nnz_levels(),
                                max_paths=3, max_candidates=3,
                                orders_per_path=1)
    assert len(cands) >= 2
    stats = SearchStats()
    ms = measure_candidates(spec, cands, arrays, factors,
                            config=MeasureConfig(warmup=1, repeats=2,
                                                 prune_ratio=1e-9),
                            stats=stats)
    assert not ms[0].pruned
    assert ms[0].candidate.key == cands[0].key
    assert stats.pruned == len(cands) - 1
    assert [m.pruned for m in ms] == [False] + [True] * (len(cands) - 1)


# --------------------------------------------------------------------- #
# (d3) satellite: stale-but-parseable cache entries are a clean miss
# --------------------------------------------------------------------- #
def test_cache_version_guard_rejects_doctored_v3_entry(tmp_path):
    """A v3-era entry restored under a current key name must be an
    explicit miss (version guard), not a downstream schema error — and
    the next put overwrites it."""
    cache = PlanCache(str(tmp_path))
    p = plan(S.mttkrp(8, 6, 5, 3))
    path = cache.put("k", p)
    assert cache.get("k") == p

    with open(path) as f:
        doc = json.load(f)
    assert doc["cache_version"] == CACHE_VERSION == 7
    # doctor the entry back to the v4 era: stale stamp, v4 plan schema
    doc["cache_version"] = 4
    doc["plan"]["version"] = 4
    doc["plan"].pop("block", None)
    with open(path, "w") as f:
        json.dump(doc, f)
    assert cache.get("k") is None           # clean miss, no exception

    # an entry missing the stamp entirely (pre-guard writer) also misses
    doc.pop("cache_version")
    doc["plan"]["version"] = 5
    doc["plan"]["block"] = None
    with open(path, "w") as f:
        json.dump(doc, f)
    assert cache.get("k") is None

    # the next search's put restores service
    cache.put("k", p)
    assert cache.get("k") == p
