"""Sparse-format invariants (hypothesis property tests) + serving + ring
cache + roofline HLO parser units."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import numpy as np

from repro.sparse import build_csf, from_dense, random_sparse
from repro.sparse.coo import from_coords, long_fiber_sparse
from repro.sparse.csf import level_segments


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    shape=st.tuples(st.integers(2, 8), st.integers(2, 8), st.integers(2, 8)),
    density=st.floats(0.05, 0.6), seed=st.integers(0, 999))
def test_csf_invariants(shape, density, seed):
    T = random_sparse(shape, density, seed=seed)
    hypothesis.assume(T.nnz > 0)
    csf = build_csf(T)
    # nnz^(I1..Ik) is nondecreasing in k and ends at nnz (paper §2.2)
    levels = [csf.nnz_level(p) for p in range(csf.order + 1)]
    assert levels[0] == 1 and levels[-1] == T.nnz
    assert all(a <= b for a, b in zip(levels, levels[1:]))
    # fiber coords at the leaf level reproduce the sorted COO coords
    np.testing.assert_array_equal(csf.fiber_coords(csf.order), T.coords)
    # parent chains are consistent: level_segments(k, k-1) == parent[k]
    for p in range(2, csf.order + 1):
        np.testing.assert_array_equal(level_segments(csf, p, p - 1),
                                      csf.parent[p])
    # segments are sorted (CSF order) — the §Perf sorted-reduce invariant
    for child in range(1, csf.order + 1):
        for par in range(child):
            seg = level_segments(csf, child, par)
            assert (np.diff(seg) >= 0).all()


def test_roundtrip_dense():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((5, 4, 3)) * (rng.random((5, 4, 3)) < 0.3)
    T = from_dense(a)
    np.testing.assert_array_equal(T.to_dense(), a)


def test_duplicate_coords_summed():
    T = from_coords(np.array([[0, 0], [0, 0], [1, 1]]),
                    np.array([1.0, 2.0, 5.0]), (2, 2))
    assert T.nnz == 2
    d = T.to_dense()
    assert d[0, 0] == 3.0 and d[1, 1] == 5.0


def test_long_fiber_generator_regime():
    T = long_fiber_sparse((64, 64, 256), n_fibers=32, fiber_len=16, seed=0)
    csf = build_csf(T)
    # the generator must actually produce nnz >> nnz^(IJ)
    assert csf.nnz_level(3) > 8 * csf.nnz_level(2) / 2


def test_ring_cache_matches_full_cache():
    """Sliding-window ring cache (O(window)) must reproduce full-cache
    decode logits exactly (§Perf gemma3 long-context memory)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import decode_step, init_cache, model_init
    cfg = get_reduced("gemma3-1b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 3 * cfg.window
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    full = init_cache(cfg, B, S, ring=False)
    ring = init_cache(cfg, B, S, ring=True)
    assert sum(x.size for x in jax.tree.leaves(ring)) < \
        sum(x.size for x in jax.tree.leaves(full))
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    for t in range(S):
        tk = jnp.asarray(toks[:, t:t + 1])
        lf, full = step(full, tk, jnp.asarray(t, jnp.int32))
        lr, ring = step(ring, tk, jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                                   atol=1e-3)


def test_server_continuous_batching():
    import jax
    from repro.configs import get_reduced
    from repro.models import model_init
    from repro.serve.serve_step import Request, Server
    cfg = get_reduced("smollm-135m")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, slots=2, cache_len=32)
    rng = np.random.default_rng(0)
    for _ in range(4):  # more requests than slots: refill path exercised
        srv.submit(Request(prompt=rng.integers(0, cfg.vocab, 6)
                           .astype(np.int32), max_new=5))
    done = srv.run(max_steps=64)
    assert len(done) == 4
    assert all(len(r.out) >= 5 for r in done)


def test_collective_parser():
    from repro.launch.roofline import collective_bytes_from_hlo
    hlo = """
HloModule test
%body.1 (arg: f32[8]) -> f32[8] {
  %ag.1 = f32[64,128]{1,0} all-gather(f32[4,128]{1,0} %x), dimensions={0}
  %ar.1 = bf16[256]{0} all-reduce(bf16[256]{0} %y), to_apply=%sum
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = f32[8]{0} while(f32[8]{0} %init), body=%body.1, condition=%c
  %rs = f32[32,32]{1,0} reduce-scatter(f32[32,32]{1,0} %z), dimensions={0}
}
"""
    out = collective_bytes_from_hlo(hlo, [10])
    per = out["per_op_bytes"]
    assert per["all-gather"] == 10 * 64 * 128 * 4      # in while body x10
    assert per["all-reduce"] == 10 * 256 * 2
    assert per["reduce-scatter"] == 32 * 32 * 4        # entry: x1
    # wire: all-reduce charged 2x
    assert out["wire_bytes"] == (10 * 64 * 128 * 4 + 2 * 10 * 256 * 2
                                 + 32 * 32 * 4)


def test_roofline_memory_model():
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import active_params, analytic_memory, total_params
    cfg = get_config("smollm-135m")
    n = active_params(cfg)
    assert 1.0e8 < n < 1.8e8            # ~135M
    moe = get_config("granite-moe-1b-a400m")
    assert total_params(moe) > 2.5 * active_params(moe)  # 32e vs top-8
    am = analytic_memory(cfg, SHAPES["train_4k"], 256, False)
    assert am["fits_16GiB"]
