"""Distributed plan replay (DESIGN.md §7, docs/distributed.md).

Covers: (a) sharded execution through per-shard tuned backends matches the
single-device Algorithm-2 reference to 1e-5 on MTTKRP and TTMc, with each
shard's plan landing in (and replaying from) the mesh-keyed plan cache;
(b) the cache key's mesh component — a sharded pattern never reuses a
single-device winner, and changing the mesh axis is a miss; (c) plan JSON
v5 round-trips the mesh/shard fields and rejects v4; (d) ``execute_plan``
over sharded operands sums per-shard partials exactly; (e) the codegen
strategy choice consumes per-shard segment profiles.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.autotune import TunerConfig, cache_key, tune
from repro.core import spec as S
from repro.core.executor import (CSFArrays, dense_oracle, execute_plan,
                                 plan_from_dict, plan_from_json,
                                 plan_to_dict, plan_to_json)
from repro.core.planner import plan
from repro.distributed import partition_nonzeros, shard_mesh_key
from repro.kernels.codegen import PallasPlanExecutor, segment_profile
from repro.sparse import build_csf, random_sparse
from tests.conftest import run_with_devices

FAST = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                   warmup=1, repeats=2)


# --------------------------------------------------------------------- #
# (a) sharded-vs-single-device parity + per-shard cached tuned backends
# --------------------------------------------------------------------- #
def test_distributed_replay_parity_and_per_shard_cache(tmp_path):
    code = f"""
import json
import os
import numpy as np
import jax
import jax.numpy as jnp
from repro.autotune import TunerConfig
from repro.core import spec as S
from repro.core.executor import reference_execute
from repro.core.planner import plan
from repro.distributed import make_distributed_tuned
from repro.sparse import build_csf, random_sparse

cache_dir = {str(tmp_path)!r}
mesh = jax.make_mesh((4,), ("data",))
cfg = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                  warmup=1, repeats=2)
rng = np.random.default_rng(0)

for name, spec, shape in [
        ("mttkrp", S.mttkrp(16, 12, 10, 8), (16, 12, 10)),
        ("ttmc", S.ttmc3(16, 12, 10, 6, 5), (16, 12, 10))]:
    T = random_sparse(shape, 0.1, seed=2)
    csf = build_csf(T)
    factors = {{t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}}
    d = os.path.join(cache_dir, name)
    dist = make_distributed_tuned(spec, T, mesh, {{0: "data"}},
                                  cache_dir=d, tuner=cfg)
    out = dist(factors)
    single = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, single.path, single.order, csf,
                            {{k: np.asarray(v) for k, v in factors.items()}})
    np.testing.assert_allclose(out, ref, atol=1e-5)

    # every live shard tuned (cold) and its winner went to the cache
    live = [sh for sh in dist.shards if sh.plan is not None]
    assert live and all(not sh.stats.cache_hit for sh in live)
    # cache inspection: one mesh-keyed entry per shard, each carrying the
    # shard context and the tuned backend in plan JSON v5
    entries = sorted(os.listdir(d))
    assert len(entries) == len(live), (entries, len(live))
    shards_seen, backends_seen = set(), set()
    for fname in entries:
        with open(os.path.join(d, fname)) as f:
            doc = json.load(f)
        assert doc["plan"]["version"] == 6
        m = doc["plan"]["mesh"]
        assert m["mesh_shape"] == {{"data": 4}}
        assert m["mode_axis"] == {{"0": "data"}}
        shards_seen.add(m["shard"])
        backends_seen.add(doc["plan"]["backend"])
    assert shards_seen == {{sh.index for sh in live}}

    # replay from cache: zero executions, same plans, same output
    dist2 = make_distributed_tuned(spec, T, mesh, {{0: "data"}},
                                   cache_dir=d, tuner=cfg)
    live2 = [sh for sh in dist2.shards if sh.plan is not None]
    assert all(sh.stats.cache_hit and sh.stats.executions == 0
               for sh in live2)
    assert [sh.plan for sh in live2] == [sh.plan for sh in live]
    # each shard executes through its cached tuned backend
    assert {{sh.plan.backend for sh in live2}} == backends_seen
    np.testing.assert_allclose(dist2(factors), ref, atol=1e-5)
    print(name.upper() + "-REPLAY-OK", dist.mode)

# forced-pallas axis: a homogeneous generated-kernel winner now routes
# through the stacked shard_map engine (one kernel trace for all shards);
# prefer_collective=False still exercises shard-by-shard replay
spec = S.mttkrp(16, 12, 10, 8)
T = random_sparse((16, 12, 10), 0.1, seed=2)
csf = build_csf(T)
factors = {{t.name: jnp.asarray(rng.standard_normal(
    [spec.dims[i] for i in t.indices]).astype(np.float32))
    for t in spec.inputs if not t.is_sparse}}
forced = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                     warmup=1, repeats=2, backends=("pallas",))
distp = make_distributed_tuned(spec, T, mesh, {{0: "data"}}, tuner=forced,
                               block=8, prefer_collective=False)
assert distp.mode == "replay"
assert all(b == "pallas" for b in distp.backends if b is not None)
single = plan(spec, nnz_levels=csf.nnz_levels())
ref = reference_execute(spec, single.path, single.order, csf,
                        {{k: np.asarray(v) for k, v in factors.items()}})
np.testing.assert_allclose(distp(factors), ref, atol=1e-5)
print("PALLAS-REPLAY-OK")
"""
    out = run_with_devices(code, 8)
    assert "MTTKRP-REPLAY-OK" in out
    assert "TTMC-REPLAY-OK" in out
    assert "PALLAS-REPLAY-OK" in out


# --------------------------------------------------------------------- #
# (b) mesh component of the cache key
# --------------------------------------------------------------------- #
def test_mesh_component_changes_cache_key():
    spec = S.mttkrp(16, 12, 10, 8)
    levels = {0: 1, 1: 14, 2: 80, 3: 190}
    single = cache_key(spec, levels, "cpu:x")
    k_data = cache_key(spec, levels, "cpu:x",
                       mesh=shard_mesh_key({"data": 4}, {0: "data"}, 0))
    k_model = cache_key(spec, levels, "cpu:x",
                        mesh=shard_mesh_key({"model": 4}, {0: "model"}, 0))
    k_mode1 = cache_key(spec, levels, "cpu:x",
                        mesh=shard_mesh_key({"data": 4}, {1: "data"}, 0))
    k_shard1 = cache_key(spec, levels, "cpu:x",
                         mesh=shard_mesh_key({"data": 4}, {0: "data"}, 1))
    k_wider = cache_key(spec, levels, "cpu:x",
                        mesh=shard_mesh_key({"data": 8}, {0: "data"}, 0))
    keys = {single, k_data, k_model, k_mode1, k_shard1, k_wider}
    assert len(keys) == 6      # all pairwise distinct


def test_sharded_search_misses_single_device_entry(tmp_path):
    """The same local nnz profile under a mesh context must not be served
    the single-device winner, and a mesh-axis change is a fresh search."""
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    p0, s0 = tune(spec, csf=csf, cache_dir=str(tmp_path), tuner=FAST)
    assert not s0.cache_hit and p0.mesh is None

    sharded = dataclasses.replace(
        FAST, mesh=shard_mesh_key({"data": 2}, {0: "data"}, 0))
    p1, s1 = tune(spec, csf=csf, cache_dir=str(tmp_path), tuner=sharded)
    assert not s1.cache_hit                 # never reuses the 1-device plan
    assert s1.cache_key != s0.cache_key
    assert p1.mesh == sharded.mesh          # plan carries the shard context

    p2, s2 = tune(spec, csf=csf, cache_dir=str(tmp_path), tuner=sharded)
    assert s2.cache_hit and s2.executions == 0 and p2 == p1

    moved = dataclasses.replace(
        FAST, mesh=shard_mesh_key({"model": 2}, {0: "model"}, 0))
    p3, s3 = tune(spec, csf=csf, cache_dir=str(tmp_path), tuner=moved)
    assert not s3.cache_hit                 # mesh axis changed -> miss
    assert s3.cache_key != s1.cache_key


# --------------------------------------------------------------------- #
# (c) plan JSON v5: mesh fields round-trip, v4 rejected
# --------------------------------------------------------------------- #
def test_plan_json_v5_mesh_round_trip():
    p = plan(S.mttkrp(8, 6, 5, 3))
    tagged = dataclasses.replace(
        p, mesh=shard_mesh_key({"data": 4}, {0: "data"}, 2))
    doc = plan_to_dict(tagged)
    assert doc["version"] == 6
    assert doc["mesh"]["shard"] == 2
    rt = plan_from_json(plan_to_json(tagged))
    assert rt == tagged and rt.mesh == tagged.mesh
    assert plan_from_json(plan_to_json(p)).mesh is None


def test_plan_json_rejects_v4_and_bad_mesh():
    doc = plan_to_dict(plan(S.mttkrp(8, 6, 5, 3)))
    doc2 = dict(doc, version=4)
    with pytest.raises(ValueError, match="unsupported plan version"):
        plan_from_dict(doc2)
    doc3 = dict(doc, mesh="data:4")
    with pytest.raises(ValueError, match="plan mesh"):
        plan_from_dict(doc3)


# --------------------------------------------------------------------- #
# (d) execute_plan over sharded operands
# --------------------------------------------------------------------- #
def _mttkrp_case():
    spec = S.mttkrp(16, 12, 10, 8)
    coo = random_sparse((16, 12, 10), 0.1, seed=2)
    csf = build_csf(coo)
    rng = np.random.default_rng(0)
    factors = {"B": rng.standard_normal((12, 8)).astype(np.float32),
               "C": rng.standard_normal((10, 8)).astype(np.float32)}
    return spec, coo, csf, factors


def test_execute_plan_sharded_operands_sum_exactly():
    spec, coo, csf, factors = _mttkrp_case()
    p = plan(spec, nnz_levels=csf.nnz_levels())
    parts = partition_nonzeros(coo, {0: 4})
    assert sum(c.nnz for c in parts) == coo.nnz
    assert all(c.shape == coo.shape for c in parts)   # global coordinates
    shards = [CSFArrays.from_csf(build_csf(c)) for c in parts if c.nnz]
    out = np.asarray(execute_plan(p, shards, factors))
    oracle = dense_oracle(spec, csf, factors)
    np.testing.assert_allclose(out, oracle, atol=1e-5)
    # per-shard factor list of the wrong length is rejected
    with pytest.raises(ValueError, match="factor mappings"):
        execute_plan(p, shards, [factors] * (len(shards) + 1))


def test_execute_plan_sharded_rejects_sparse_output():
    spec = S.tttp3(8, 6, 5, 4)
    coo = random_sparse((8, 6, 5), 0.2, seed=1)
    p = plan(spec)
    shards = [CSFArrays.from_csf(build_csf(c))
              for c in partition_nonzeros(coo, {0: 2}) if c.nnz]
    rng = np.random.default_rng(0)
    factors = {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32)
        for t in spec.inputs if not t.is_sparse}
    with pytest.raises(ValueError, match="same-sparsity"):
        execute_plan(p, shards, factors)


# --------------------------------------------------------------------- #
# (e) per-shard segment profiles feed the codegen strategy choice
# --------------------------------------------------------------------- #
def test_strategy_consumes_per_shard_segment_profile():
    spec, coo, csf, _ = _mttkrp_case()
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True)
    shard_arrays = [CSFArrays.from_csf(build_csf(c))
                    for c in partition_nonzeros(coo, {0: 4}) if c.nnz]
    for arrays in shard_arrays:
        for lvl, out_lvl in [(3, 1), (2, 1), (3, 2)]:
            prof = segment_profile(arrays, lvl, out_lvl)
            assert prof.nfib == arrays.nfib[lvl]
            assert prof.nseg == arrays.nfib[out_lvl]
            assert prof.max_seg >= 1 and prof.mean_seg > 0
            want = "row" if prof.prefers_row(ex.block) else "segsum"
            assert ex.strategy_for(arrays, lvl, out_lvl) == want
    # profiles are genuinely per shard: fiber counts differ across shards
    assert len({a.nfib[3] for a in shard_arrays}) > 1
    # and executing records the trace-time choice for inspection
    rng = np.random.default_rng(0)
    factors = {"B": rng.standard_normal((12, 8)).astype(np.float32),
               "C": rng.standard_normal((10, 8)).astype(np.float32)}
    ex(shard_arrays[0], factors)
    assert ex.stage_strategy and set(ex.stage_strategy.values()) <= {
        "row", "segsum"}
