"""Static plan verifier (DESIGN.md §11).

Covers: (a) ``verify_plan`` accepts every planner-emitted plan for all
four paper kernels; (b) each seeded single-axis mutation is rejected
with its stable SPTTN-E* code; (c) the legacy legality sites —
``fusible_chains``, ``stackable_plan``, ``_check_block_grid``,
``sliced_execute``, ``plan_from_json`` — are thin wrappers over the
verifier (no duplicated invariant logic); (d) ``execute_plan`` refuses
an illegal plan pre-flight with :class:`PlanVerificationError`; (e) the
tuner's verification gate reports ``SearchStats.vetoed``; (f) the facade
exports; (g) the docs code table stays in sync with the registry.
"""
import dataclasses
import json
import os
import re

import numpy as np
import pytest

from repro.analysis import (DIAGNOSTIC_CODES, Diagnostic, PlanReport,
                            PlanVerificationError, diag, verify_plan)
from repro.analysis import invariants as inv
from repro.core import spec as S
from repro.core.executor import (CSFArrays, execute_plan, plan_from_json,
                                 plan_to_json)
from repro.core.planner import plan as make_plan
from repro.sparse import build_csf, random_sparse

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPECS = {
    "mttkrp": S.mttkrp(6, 5, 4, 3),
    "ttmc3": S.ttmc3(5, 4, 3, 3, 2),
    "tttp3": S.tttp3(5, 4, 3, 3),
    "tttc6": S.tttc6(3, 2),
}


def _inputs_for(spec, seed=0):
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, 0.3, seed=seed))
    rng = np.random.default_rng(seed)
    factors = {t.name: rng.standard_normal(
                   [spec.dims[i] for i in t.indices]).astype(np.float32)
               for t in spec.inputs if not t.is_sparse}
    return csf, factors


# --------------------------------------------------------------------- #
# (a) planner plans verify clean on every paper kernel
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(SPECS))
def test_planner_plans_verify_clean(name):
    p = make_plan(SPECS[name])
    rep = verify_plan(p)
    assert rep.ok, f"{name}: planner plan rejected: {rep.codes}"
    assert not rep.errors
    assert rep.raise_if_error() is rep     # no-op on a legal plan


# --------------------------------------------------------------------- #
# (b) seeded mutations -> stable codes
# --------------------------------------------------------------------- #
def _mutations():
    p = make_plan(SPECS["mttkrp"])
    p_sp = make_plan(SPECS["tttp3"])       # same-sparsity output, no chain
    sp0 = p.spec.sparse_indices[0]
    return [
        ("order-length", "SPTTN-E003",
         lambda: verify_plan(p.spec, p.path, p.order[:-1])),
        ("not-a-permutation", "SPTTN-E002",
         lambda: verify_plan(p.spec, p.path,
                             (p.order[0][:-1],) + p.order[1:])),
        ("wrong-final-output", "SPTTN-E004",
         lambda: verify_plan(p.spec, p.path[:-1], p.order[:-1])),
        ("fused-without-chain", "SPTTN-E010",
         lambda: verify_plan(p_sp, fused=True)),
        ("block-not-positive", "SPTTN-E020",
         lambda: verify_plan(dataclasses.replace(p, block=0))),
        ("block-misaligned", "SPTTN-E021",
         lambda: verify_plan(dataclasses.replace(p, block=100))),
        ("slice-unknown-mode", "SPTTN-E030",
         lambda: verify_plan(dataclasses.replace(
             p, slice_mode="q", slice_chunks=2))),
        ("slice-sparse-mode", "SPTTN-E031",
         lambda: verify_plan(dataclasses.replace(
             p, slice_mode=sp0, slice_chunks=2))),
        ("slice-chunks-range", "SPTTN-E032",
         lambda: verify_plan(dataclasses.replace(
             p, slice_mode="a", slice_chunks=10**6))),
        ("slice-chunks-no-mode", "SPTTN-E033",
         lambda: verify_plan(dataclasses.replace(p, slice_chunks=4))),
        ("unknown-backend", "SPTTN-E040",
         lambda: verify_plan(p, backend="tpu")),
        ("mesh-malformed", "SPTTN-E050",
         lambda: verify_plan(dataclasses.replace(p, mesh={"mesh_shape": 3}))),
        ("sparse-output-stacked", "SPTTN-E052",
         lambda: verify_plan(p_sp, stacked=True)),
    ]


@pytest.mark.parametrize("label,code,run", _mutations(),
                         ids=[m[0] for m in _mutations()])
def test_mutation_rejected_with_code(label, code, run):
    rep = run()
    assert code in rep.codes, f"{label}: {rep.codes}"
    assert not rep.ok
    with pytest.raises(PlanVerificationError) as ei:
        rep.raise_if_error("test")
    assert code in str(ei.value)
    assert ei.value.report is rep


def test_storage_prefix_mutation_rejected():
    # permute the two deepest sparse levels of whichever term carries them
    p = make_plan(SPECS["mttkrp"])
    sparse = set(p.spec.sparse_indices)
    mutated = None
    for i, a in enumerate(p.order):
        sp = [x for x in a if x in sparse]
        if len(sp) >= 2:
            b = list(a)
            u, v = b.index(sp[0]), b.index(sp[1])
            b[u], b[v] = b[v], b[u]
            mutated = p.order[:i] + (tuple(b),) + p.order[i + 1:]
            break
    assert mutated is not None
    rep = verify_plan(p.spec, p.path, mutated)
    assert "SPTTN-E001" in rep.codes


# --------------------------------------------------------------------- #
# (c) legacy sites are wrappers — the invariant logic lives once
# --------------------------------------------------------------------- #
def test_codegen_chain_detector_is_the_verifiers():
    from repro.kernels.codegen import executor as codegen
    assert codegen.fusible_chains is inv.fusible_chains


@pytest.mark.parametrize("name,expect", [("mttkrp", True), ("tttp3", False)])
def test_stackable_plan_agrees_with_diagnostics(name, expect):
    from repro.distributed.spttn_dist import stackable_plan
    p = make_plan(SPECS[name])
    assert stackable_plan(p.spec, p.path) is expect
    diags = inv.stackable_diagnostics(p.spec, p.path)
    assert (not diags) is expect
    if not expect:
        assert diags[0].code == "SPTTN-E052"


def test_block_grid_wrapper_raises_with_code():
    from repro.kernels.codegen.stages import _check_block_grid
    with pytest.raises(ValueError, match=r"SPTTN-E022"):
        _check_block_grid(130, 128)
    _check_block_grid(256, 128)            # divisible: silent


# --------------------------------------------------------------------- #
# backend portability codes (docs/backends.md)
# --------------------------------------------------------------------- #
def test_unregistered_lowering_rejected_with_code(monkeypatch):
    from repro.kernels.codegen import ir
    p = make_plan(SPECS["mttkrp"])
    gpu = dataclasses.replace(p, backend="pallas-gpu")
    assert verify_plan(gpu).ok             # both built-ins registered
    monkeypatch.delitem(ir._LOWERINGS, "gpu")
    rep = verify_plan(gpu)
    assert "SPTTN-E041" in rep.codes
    assert not rep.ok
    # the TPU target is untouched — only the missing one is rejected
    assert verify_plan(dataclasses.replace(p, backend="pallas")).ok
    # the engine registry reports the same condition as a ValueError
    with pytest.raises(ValueError, match="no stage lowering"):
        ir.get_lowering("gpu")


def test_device_kind_mismatch_warns_never_blocks():
    p = make_plan(SPECS["mttkrp"])
    gpu = dataclasses.replace(p, backend="pallas-gpu")
    rep = verify_plan(gpu, device_kind="tpu")
    assert "SPTTN-W005" in rep.codes
    assert rep.ok                          # warnings never block
    # matching device kind, non-Pallas backends, and the default
    # (device kind unstated — the CPU witness convention) stay silent
    assert "SPTTN-W005" not in verify_plan(gpu, device_kind="gpu").codes
    assert "SPTTN-W005" not in verify_plan(p, device_kind="gpu").codes
    assert "SPTTN-W005" not in verify_plan(gpu).codes


def test_sliced_execute_refuses_sparse_mode_with_code():
    from repro.core.slicing import sliced_execute
    p = make_plan(SPECS["mttkrp"])
    csf, factors = _inputs_for(p.spec)
    bad = dataclasses.replace(p, slice_mode=p.spec.sparse_indices[0],
                              slice_chunks=2)
    with pytest.raises(ValueError, match=r"SPTTN-E031"):
        sliced_execute(bad, csf, factors)


@pytest.mark.parametrize("patch,code", [
    ({"version": 5}, "SPTTN-E060"),
    ({"backend": "tpu"}, "SPTTN-E040"),
    ({"block": 100}, "SPTTN-E021"),
    ({"mesh": {"mesh_shape": 3}}, "SPTTN-E050"),
])
def test_plan_json_load_rejects_with_code(patch, code):
    p = make_plan(SPECS["mttkrp"])
    doc = json.loads(plan_to_json(p))
    doc.update(patch)
    with pytest.raises(ValueError, match=code):
        plan_from_json(json.dumps(doc))


# --------------------------------------------------------------------- #
# (d) execute_plan pre-flight
# --------------------------------------------------------------------- #
def test_execute_plan_preflight_rejects_doctored_plan():
    p = make_plan(SPECS["tttp3"])
    csf, factors = _inputs_for(p.spec)
    bad = dataclasses.replace(p, fused=True)   # no chain on tttp3
    with pytest.raises(PlanVerificationError, match=r"SPTTN-E010"):
        execute_plan(bad, CSFArrays.from_csf(csf), factors)


# --------------------------------------------------------------------- #
# (e) tuner verification gate
# --------------------------------------------------------------------- #
def test_tune_reports_vetoed_stat():
    from repro.autotune import TunerConfig, tune
    spec = S.mttkrp(16, 12, 8, 4)
    csf, factors = _inputs_for(spec, seed=3)
    cfg = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                      warmup=0, repeats=1)
    tuned, stats = tune(spec, csf=csf, factors=factors, tuner=cfg)
    assert stats.vetoed == 0               # generator emits only legal plans
    assert stats.candidates_generated >= 1
    assert verify_plan(tuned).ok


# --------------------------------------------------------------------- #
# (f) facade + diagnostics plumbing
# --------------------------------------------------------------------- #
def test_facade_exports_verifier():
    import repro
    from repro.analysis import verify as V
    assert repro.verify_plan is V.verify_plan
    assert repro.Diagnostic is Diagnostic
    assert repro.PlanReport is PlanReport
    assert repro.PlanVerificationError is PlanVerificationError


def test_diagnostic_codes_are_registered_and_typed():
    with pytest.raises(ValueError, match="unregistered"):
        Diagnostic(code="SPTTN-E999", severity="error",
                   stage_ref="x", message="m")
    d = diag("SPTTN-W003", "term[0]", "big scratch", fix_hint="slice it")
    assert d.severity == "warning"
    assert "fix: slice it" in str(d)
    assert diag("SPTTN-E001", "order[0]", "m").severity == "error"
    rep = PlanReport(diagnostics=(d,))
    assert rep.ok and bool(rep) and rep.warnings == (d,)


# --------------------------------------------------------------------- #
# (g) docs table <-> registry sync
# --------------------------------------------------------------------- #
def test_docs_code_table_matches_registry():
    path = os.path.join(REPO, "docs", "analysis.md")
    with open(path) as f:
        text = f.read()
    in_docs = set(re.findall(r"`(SPTTN-[EW]\d{3})`", text))
    assert in_docs == set(DIAGNOSTIC_CODES), (
        f"docs/analysis.md table out of sync: "
        f"missing={sorted(set(DIAGNOSTIC_CODES) - in_docs)} "
        f"stale={sorted(in_docs - set(DIAGNOSTIC_CODES))}")
