"""Stacked-layout Pallas under shard_map (DESIGN.md §7, docs/distributed.md).

The tentpole claim: padding every shard's CSF + block layouts to a common
stacked ``(n_shards, ...)`` layout lets ONE ``pallas_call`` trace serve
every shard inside shard_map, with contracted-mode partials reduced by
psum — no host round trip, no per-shard retrace.  Covers:

(a) collective-pallas parity vs the Algorithm-2 reference on MTTKRP and
    TTMc at mesh sizes 1/2/4, routed through ``make_distributed_tuned``
    (homogeneous forced-pallas winners), plus the psum path (contracted
    mode partitioned);
(b) the trace-count spy: the number of ``pallas_call`` invocations is
    independent of mesh size — one kernel trace for all shards;
(c) edge cases: an entirely empty shard slot, a single-shard mesh,
    all-singleton segments;
(d) ``stackable_plan`` structural gating and the sparse-output rejection;
(e) the plan-cache ``dist_mode`` annotation written by the router.

The hypothesis property suite for the stacked padding lives in
tests/test_stacked_hypothesis.py (skipped where hypothesis is absent).
"""
import numpy as np
import pytest

from repro.autotune.cache import PlanCache
from repro.core import spec as S
from repro.core.executor import dense_oracle
from repro.core.planner import plan
from repro.distributed import stackable_plan
from repro.distributed.spttn_dist import undo_cyclic
from repro.sparse import build_csf, random_sparse
from tests.conftest import run_with_devices


def _dense_factors(spec, rng):
    import jax.numpy as jnp
    return {t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}


# --------------------------------------------------------------------- #
# (d) structural gating — host-side, no devices needed
# --------------------------------------------------------------------- #
def test_stackable_plan_paper_kernels():
    for spec, shape in [(S.mttkrp(16, 12, 10, 8), (16, 12, 10)),
                        (S.ttmc3(16, 12, 10, 6, 5), (16, 12, 10)),
                        (S.ttmc4(8, 6, 5, 4, 3, 3, 3), (8, 6, 5, 4))]:
        csf = build_csf(random_sparse(shape, 0.1, seed=2))
        pl = plan(spec, nnz_levels=csf.nnz_levels())
        assert stackable_plan(spec, pl.path)
        assert stackable_plan(spec, pl.path, fused=True)


def test_stackable_plan_rejects_sparse_output():
    spec = S.tttp3(8, 6, 5, 4)
    pl = plan(spec)
    assert not stackable_plan(spec, pl.path)


def test_make_distributed_pallas_rejects_sparse_output():
    import jax
    from repro.distributed import make_distributed_pallas
    spec = S.tttp3(8, 6, 5, 4)
    T = random_sparse((8, 6, 5), 0.2, seed=0)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="dense output"):
        make_distributed_pallas(spec, plan(spec), T, mesh, {0: "data"})


# --------------------------------------------------------------------- #
# single-shard mesh runs in-process (1 CPU device is enough)
# --------------------------------------------------------------------- #
def test_stacked_single_shard_parity_in_process():
    import jax
    from repro.distributed import make_distributed_pallas
    spec = S.mttkrp(16, 12, 10, 8)
    T = random_sparse((16, 12, 10), 0.1, seed=2)
    csf = build_csf(T)
    rng = np.random.default_rng(0)
    factors = _dense_factors(spec, rng)
    pl = plan(spec, nnz_levels=csf.nnz_levels())
    mesh = jax.make_mesh((1,), ("data",))
    dist = make_distributed_pallas(spec, pl, T, mesh, {0: "data"})
    out = undo_cyclic(np.asarray(dist(factors)), spec, {0: "data"}, mesh,
                      T.shape)[:16]
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(out, oracle, atol=1e-5)


# --------------------------------------------------------------------- #
# (e) the router annotates the plan-cache entries with the chosen mode
# --------------------------------------------------------------------- #
def test_tuned_routing_annotates_dist_mode(tmp_path):
    import jax
    from repro.autotune import TunerConfig
    from repro.distributed import make_distributed_tuned
    spec = S.mttkrp(16, 12, 10, 8)
    T = random_sparse((16, 12, 10), 0.1, seed=2)
    rng = np.random.default_rng(0)
    factors = _dense_factors(spec, rng)
    mesh = jax.make_mesh((1,), ("data",))
    cfg = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                      warmup=1, repeats=2, backends=("pallas",))
    dist = make_distributed_tuned(spec, T, mesh, {0: "data"},
                                  cache_dir=str(tmp_path), tuner=cfg)
    assert dist.mode == "collective-pallas"
    assert dist.collective is not None
    cache = PlanCache(str(tmp_path))
    live = [sh for sh in dist.shards if sh.plan is not None]
    assert live
    for sh in live:
        meta = cache.meta(sh.stats.cache_key)
        assert meta is not None and meta["dist_mode"] == "collective-pallas"
    # parity through the tuned router too
    csf = build_csf(T)
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(np.asarray(dist(factors))[:16], oracle,
                               atol=1e-5)


def test_annotate_missing_key_is_noop(tmp_path):
    cache = PlanCache(str(tmp_path))
    assert cache.annotate("nope", dist_mode="replay") is False
    assert cache.meta("nope") is None


# --------------------------------------------------------------------- #
# (a) multi-device parity: mesh 1/2/4, MTTKRP + TTMc, psum path
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_stacked_parity_across_meshes():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from repro.autotune import TunerConfig
from repro.core import spec as S
from repro.core.executor import reference_execute
from repro.core.planner import plan
from repro.distributed import make_distributed_pallas, make_distributed_tuned
from repro.distributed.spttn_dist import undo_cyclic
from repro.sparse import build_csf, random_sparse

rng = np.random.default_rng(0)
forced = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                     warmup=1, repeats=2, backends=("pallas",))
for name, spec, shape in [
        ("mttkrp", S.mttkrp(16, 12, 10, 8), (16, 12, 10)),
        ("ttmc", S.ttmc3(16, 12, 10, 6, 5), (16, 12, 10))]:
    T = random_sparse(shape, 0.1, seed=2)
    csf = build_csf(T)
    factors = {t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}
    single = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, single.path, single.order, csf,
                            {k: np.asarray(v) for k, v in factors.items()})
    for n in (1, 2, 4):
        mesh = jax.make_mesh((n,), ("data",))
        dist = make_distributed_tuned(spec, T, mesh, {0: "data"},
                                      tuner=forced, block=8)
        assert dist.mode == "collective-pallas", (name, n, dist.mode)
        out = np.asarray(dist(factors))
        np.testing.assert_allclose(out, ref, atol=1e-5)
        print(f"{name.upper()}-MESH{n}-OK")

# psum path: partition the CONTRACTED mode j — partials must reduce
# inside shard_map, not on host
spec = S.mttkrp(16, 12, 10, 8)
T = random_sparse((16, 12, 10), 0.1, seed=2)
csf = build_csf(T)
factors = {t.name: jnp.asarray(rng.standard_normal(
    [spec.dims[i] for i in t.indices]).astype(np.float32))
    for t in spec.inputs if not t.is_sparse}
pl = plan(spec, nnz_levels=csf.nnz_levels())
single = plan(spec, nnz_levels=csf.nnz_levels())
ref = reference_execute(spec, single.path, single.order, csf,
                        {k: np.asarray(v) for k, v in factors.items()})
for n in (2, 4):
    mesh = jax.make_mesh((n,), ("data",))
    dist = make_distributed_pallas(spec, pl, T, mesh, {1: "data"})
    out = np.asarray(dist(factors))
    out = undo_cyclic(out, spec, {1: "data"}, mesh, T.shape)
    np.testing.assert_allclose(out, ref, atol=1e-5)
    print(f"PSUM-MESH{n}-OK")
"""
    out = run_with_devices(code, 4)
    for tag in ("MTTKRP-MESH1-OK", "MTTKRP-MESH2-OK", "MTTKRP-MESH4-OK",
                "TTMC-MESH1-OK", "TTMC-MESH2-OK", "TTMC-MESH4-OK",
                "PSUM-MESH2-OK", "PSUM-MESH4-OK"):
        assert tag in out


# --------------------------------------------------------------------- #
# (b) the trace-count spy: one pallas_call trace regardless of mesh size
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_one_trace_serves_all_shards():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
import repro.kernels.codegen.stages as stages
from repro.core import spec as S
from repro.core.planner import plan
from repro.distributed import make_distributed_pallas
from repro.sparse import build_csf, random_sparse

calls = [0]
real = stages.pl.pallas_call
def spy(*a, **k):
    calls[0] += 1
    return real(*a, **k)
stages.pl.pallas_call = spy

spec = S.mttkrp(16, 12, 10, 8)
T = random_sparse((16, 12, 10), 0.1, seed=2)
csf = build_csf(T)
rng = np.random.default_rng(0)
factors = {t.name: jnp.asarray(rng.standard_normal(
    [spec.dims[i] for i in t.indices]).astype(np.float32))
    for t in spec.inputs if not t.is_sparse}
pl_ = plan(spec, nnz_levels=csf.nnz_levels())

counts = {}
for n in (1, 2, 4):
    mesh = jax.make_mesh((n,), ("data",))
    calls[0] = 0
    dist = make_distributed_pallas(spec, pl_, T, mesh, {0: "data"})
    dist(factors)            # build + first (tracing) execution
    counts[n] = calls[0]
print("COUNTS", counts)
assert counts[1] > 0
# the kernel trace count must not grow with the number of shards
assert counts[1] == counts[2] == counts[4], counts
print("ONE-TRACE-OK")
"""
    out = run_with_devices(code, 4)
    assert "ONE-TRACE-OK" in out


# --------------------------------------------------------------------- #
# (c) edge cases: empty shard slot, all-singleton segments
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_stacked_edge_cases():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import spec as S
from repro.core.executor import dense_oracle
from repro.core.planner import plan
from repro.distributed import make_distributed_pallas
from repro.distributed.spttn_dist import undo_cyclic
from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import COOTensor

mesh = jax.make_mesh((2,), ("data",))
rng = np.random.default_rng(0)

def check(spec, T, tag):
    csf = build_csf(T)
    factors = {t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}
    pl = plan(spec, nnz_levels=csf.nnz_levels())
    dist = make_distributed_pallas(spec, pl, T, mesh, {0: "data"})
    out = np.asarray(dist(factors))
    out = undo_cyclic(out, spec, {0: "data"}, mesh, T.shape)
    out = out[: T.shape[0]]
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(out, oracle, atol=1e-5)
    print(tag + "-OK")

spec = S.mttkrp(16, 12, 10, 8)

# empty shard slot: every nonzero on an even mode-0 row -> cyclic shard 1
# owns nothing; its stacked slot is all padding and must contribute zero
T0 = random_sparse((16, 12, 10), 0.15, seed=3)
keep = T0.coords[:, 0] % 2 == 0
Te = COOTensor(coords=np.ascontiguousarray(T0.coords[keep]),
               values=np.ascontiguousarray(T0.values[keep]),
               shape=T0.shape)
assert (Te.coords[:, 0] % 2 == 1).sum() == 0
check(spec, Te, "EMPTY-SHARD")

# all-singleton segments: one nonzero per mode-0 row, distinct (j, k) —
# every CSF fiber at every level has exactly one child
I = 16
coords = np.stack([np.arange(I), np.arange(I) % 12, np.arange(I) % 10], 1)
vals = rng.standard_normal(I).astype(np.float32)
key = np.lexsort(coords.T[::-1])
Ts = COOTensor(coords=np.ascontiguousarray(coords[key].astype(np.int64)),
               values=np.ascontiguousarray(vals[key]), shape=(16, 12, 10))
check(spec, Ts, "SINGLETON-SEGS")
"""
    out = run_with_devices(code, 2)
    assert "EMPTY-SHARD-OK" in out
    assert "SINGLETON-SEGS-OK" in out
