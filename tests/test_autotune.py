"""Autotuning runtime + persistent plan cache (DESIGN.md §4).

Covers: (a) the tuned plan's measured runtime never exceeds the model
pick's (the model pick is always in the measured candidate set); (b) plan
serialization round-trips to an identical SpTTNPlan with identical executor
output; (c) the cache key is a pure function of (spec, nnz-level profile,
device) — values never enter, pattern changes do.
"""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.autotune import (PlanCache, TunerConfig, cache_key,
                            device_kind, generate_candidates, spec_signature,
                            tune)
from repro.core import spec as S
from repro.core.executor import (CSFArrays, VectorizedExecutor, dense_oracle,
                                 plan_from_json, plan_to_json)
from repro.core.planner import plan
from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import from_coords

FAST = TunerConfig(max_paths=4, max_candidates=4, orders_per_path=2,
                   warmup=1, repeats=2)


def _mttkrp_inputs(I=32, J=24, K=16, R=8, density=0.08, seed=3):
    spec = S.mttkrp(I, J, K, R)
    csf = build_csf(random_sparse((I, J, K), density, seed=seed))
    rng = np.random.default_rng(0)
    factors = {"B": jnp.asarray(rng.standard_normal((J, R))
                                .astype(np.float32)),
               "C": jnp.asarray(rng.standard_normal((K, R))
                                .astype(np.float32))}
    return spec, csf, factors


# --------------------------------------------------------------------- #
# (a) tuned <= model-picked, measured — across several small MTTKRPs
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dims,density,seed", [
    ((32, 24, 16, 8), 0.08, 3),
    ((48, 16, 16, 4), 0.15, 11),
    ((16, 32, 24, 16), 0.05, 7),
])
def test_tuned_runtime_never_worse_than_model(dims, density, seed):
    I, J, K, R = dims
    spec, csf, factors = _mttkrp_inputs(I, J, K, R, density, seed)
    tuned, stats = tune(spec, csf=csf, factors=factors, tuner=FAST)
    # the model's pick is always measured, and the winner is the measured
    # minimum, so this holds by construction *of real measurements*
    assert stats.model_seconds is not None
    assert stats.best_seconds <= stats.model_seconds
    assert stats.candidates_timed >= 1
    assert stats.executions >= stats.candidates_timed
    # and the tuned plan computes the right answer
    out = VectorizedExecutor(spec, tuned.path, tuned.order)(
        CSFArrays.from_csf(csf), factors)
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-3)


def test_candidates_are_model_ranked_and_deduped():
    spec, csf, _ = _mttkrp_inputs()
    cands = generate_candidates(spec, nnz_levels=csf.nnz_levels(),
                                max_paths=4, max_candidates=6,
                                orders_per_path=2)
    assert 1 <= len(cands) <= 6
    assert len({c.key for c in cands}) == len(cands)
    scores = [(c.cost, c.flops) for c in cands]
    assert scores == sorted(scores)


# --------------------------------------------------------------------- #
# (b) cache round trip: identical plan, identical output
# --------------------------------------------------------------------- #
def test_plan_serialization_round_trip(tmp_path):
    spec, csf, factors = _mttkrp_inputs()
    tuned, _ = tune(spec, csf=csf, factors=factors, tuner=FAST,
                    cache_dir=str(tmp_path))
    rt = plan_from_json(plan_to_json(tuned))
    assert rt == tuned                      # full dataclass equality
    assert rt.spec == tuned.spec and rt.order == tuned.order
    arrays = CSFArrays.from_csf(csf)
    out_a = np.asarray(VectorizedExecutor(spec, tuned.path, tuned.order)(
        arrays, factors))
    out_b = np.asarray(VectorizedExecutor(rt.spec, rt.path, rt.order)(
        arrays, factors))
    np.testing.assert_array_equal(out_a, out_b)   # same program, bitwise


def test_cache_round_trip_via_disk(tmp_path):
    spec, csf, factors = _mttkrp_inputs()
    p1 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=FAST)
    assert not p1.stats.cache_hit and p1.stats.executions > 0
    p2 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=FAST)
    assert p2.stats.cache_hit
    assert p2.stats.executions == 0         # zero candidate executions
    assert p2.stats.candidates_timed == 0
    assert p1 == p2                         # identical SpTTNPlan
    # one well-formed JSON entry on disk
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 1
    with open(tmp_path / files[0]) as f:
        doc = json.load(f)
    assert "plan" in doc and "meta" in doc
    assert doc["meta"]["executions"] == p1.stats.executions


@pytest.mark.parametrize("garbage", [
    "{not json",                       # invalid JSON
    '{"plan": []}',                    # valid JSON, wrong shape
    '{"plan": {"version": 99}}',       # unknown serialization version
    '"just a string"',                 # not even an object
])
def test_corrupt_cache_entry_is_a_miss(tmp_path, garbage):
    spec, csf, factors = _mttkrp_inputs()
    p1 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=FAST)
    files = os.listdir(tmp_path)
    with open(tmp_path / files[0], "w") as f:
        f.write(garbage)
    p2 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=FAST)
    assert not p2.stats.cache_hit           # re-searched, then re-wrote
    p3 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=FAST)
    assert p3.stats.cache_hit and p1.spec == p3.spec


# --------------------------------------------------------------------- #
# (c) cache key: values don't matter, pattern does
# --------------------------------------------------------------------- #
def test_cache_key_same_pattern_different_values_hits(tmp_path):
    I, J, K, R = 24, 16, 12, 4
    spec = S.mttkrp(I, J, K, R)
    base = random_sparse((I, J, K), 0.1, seed=5)
    csf_a = build_csf(base)
    other = from_coords(base.coords.copy(),
                        (base.values * 3.0 + 1.0).astype(np.float32),
                        (I, J, K))
    csf_b = build_csf(other)
    assert not np.allclose(csf_a.values, csf_b.values)
    dev = device_kind()
    key_a = cache_key(spec, csf_a.nnz_levels(), dev)
    key_b = cache_key(spec, csf_b.nnz_levels(), dev)
    assert key_a == key_b                   # values never enter the key

    p1 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf_a,
              tuner=FAST)
    p2 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf_b,
              tuner=FAST)
    assert not p1.stats.cache_hit and p2.stats.cache_hit
    assert p1.stats.cache_key == p2.stats.cache_key

    # a different pattern (different nnz-level profile) misses
    csf_c = build_csf(random_sparse((I, J, K), 0.25, seed=9))
    assert csf_c.nnz_levels() != csf_a.nnz_levels()
    p3 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf_c,
              tuner=FAST)
    assert not p3.stats.cache_hit
    assert p3.stats.cache_key != p1.stats.cache_key


def test_cache_key_depends_on_spec_and_device():
    spec_a = S.mttkrp(24, 16, 12, 4)
    spec_b = S.mttkrp(24, 16, 12, 8)        # different rank dim
    levels = {0: 1, 1: 10, 2: 50, 3: 100}
    assert spec_signature(spec_a) != spec_signature(spec_b)
    assert (cache_key(spec_a, levels, "cpu:x") !=
            cache_key(spec_b, levels, "cpu:x"))
    assert (cache_key(spec_a, levels, "cpu:x") !=
            cache_key(spec_a, levels, "tpu:v5e"))


def test_plan_cache_atomic_put_and_get(tmp_path):
    spec, csf, factors = _mttkrp_inputs()
    tuned, stats = tune(spec, csf=csf, factors=factors, tuner=FAST)
    cache = PlanCache(str(tmp_path))
    path = cache.put("abc123", tuned, meta={"note": "t"})
    assert os.path.exists(path)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    got = cache.get("abc123")
    assert got == tuned
    assert cache.get("missing") is None
