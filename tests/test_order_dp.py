"""Algorithm 1 (Theorem 4.9): the DP optimum must equal the exhaustive
optimum over all valid loop orders, for every tree-separable cost."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st

from repro.core import spec as S
from repro.core.cost import (CacheMisses, ConstrainedBlas, MaxBufferDim,
                             MaxBufferSize)
from repro.core.enumerate import brute_force_optimal
from repro.core.order_dp import OrderDP
from repro.core.paths import min_depth_paths

COSTS = [MaxBufferDim(), MaxBufferSize(), CacheMisses(D=1), CacheMisses(D=2),
         ConstrainedBlas(2), ConstrainedBlas(1)]


@st.composite
def spttn_specs(draw):
    """Random small SpTTN: order-2/3 sparse tensor x 1-2 dense factors."""
    d = draw(st.integers(2, 3))
    sp_inds = "ijk"[:d]
    n_dense = draw(st.integers(1, 3))
    dense_specs = []
    rank_inds = "rst"
    for f in range(n_dense):
        which = draw(st.integers(0, d - 1))
        has_rank = draw(st.booleans())
        inds = sp_inds[which] + (rank_inds[f] if has_rank else "")
        if not has_rank and f > 0:
            inds = sp_inds[which] + rank_inds[0]  # share r with factor 0
        dense_specs.append(inds)
    used_ranks = sorted({c for spec in dense_specs for c in spec
                         if c in rank_inds})
    out = sp_inds[0] + "".join(used_ranks)
    dims = {c: draw(st.integers(2, 5)) for c in sp_inds + "".join(used_ranks)}
    expr = ",".join([sp_inds] + dense_specs) + "->" + out
    return S.parse(expr, dims=dims, sparse=0)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(spec=spttn_specs(), cost_i=st.integers(0, len(COSTS) - 1))
def test_dp_matches_bruteforce(spec, cost_i):
    cost = COSTS[cost_i]
    for path in min_depth_paths(spec, max_paths=4, slack=1):
        dp = OrderDP(path, cost, spec.dims, spec.sparse_indices).solve()
        bf_order, bf_cost = brute_force_optimal(path, cost, spec.dims,
                                                spec.sparse_indices)
        if bf_cost == float("inf"):
            # constraint infeasible for every order: DP must agree
            assert dp.cost == float("inf")
            continue
        assert dp.order is not None
        assert abs(dp.cost - bf_cost) < 1e-9, (
            f"{type(cost).__name__}: dp={dp.cost} bf={bf_cost}\n"
            f"dp_order={dp.order}\nbf_order={bf_order}\n"
            f"path={[str(t) for t in path]}")
        # the DP's own order must evaluate to its claimed cost
        assert abs(cost.evaluate(path, dp.order, spec.dims,
                                 spec.sparse_indices) - dp.cost) < 1e-9


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(spec=spttn_specs())
def test_dp_second_best_has_different_root(spec):
    cost = MaxBufferSize()
    for path in min_depth_paths(spec, max_paths=2):
        dp = OrderDP(path, cost, spec.dims, spec.sparse_indices).solve()
        if dp.alt_order is None:
            continue
        root_a = next(a[0] for a in dp.order if a)
        root_b = next(a[0] for a in dp.alt_order if a)
        assert root_a != root_b
        assert dp.alt_cost >= dp.cost


def test_paper_ttmc_example():
    """Paper §3.3/Fig 1: TTMc admits a scalar-intermediate loop nest; the
    max-buffer-dim optimum over the (T.V then .U) path is 0 (a scalar)."""
    sp = S.ttmc3(8, 8, 8, 4, 4)
    best = None
    for path in min_depth_paths(sp):
        dp = OrderDP(path, MaxBufferDim(), sp.dims, sp.sparse_indices).solve()
        best = dp.cost if best is None else min(best, dp.cost)
    assert best == 0  # Listing 5: X is a scalar


def test_blas_metric_prefers_vector_intermediate():
    """Paper Fig 10c: the BLAS metric picks the vector-intermediate order
    (i,j,k,s) over the scalar one (i,j,s,k) for the T.V term."""
    sp = S.ttmc3(8, 8, 8, 4, 4)
    cost = ConstrainedBlas(2)
    found = False
    for path in min_depth_paths(sp):
        if "(T.V)" not in path[0].out.name:
            continue
        dp = OrderDP(path, cost, sp.dims, sp.sparse_indices).solve()
        # T.V term order must end with the dense index s (BLAS-able axpy)
        assert dp.order[0][-1] == "s"
        found = True
    assert found
