"""Memory-budgeted sliced execution (core/slicing.py, DESIGN.md §10).

Covers: (a) sliced replay is exact — 1e-5 parity vs unsliced execution
on MTTKRP/TTMc (output-mode slabs) and TTTP (contracted-mode
accumulation), non-divisible chunk tails included; (b) the budget is
honored — every chunk's MaxBufferSize-based footprint, tail included,
prices at or under the budget; (c) one cached plan — a budgeted tune
persists exactly one UNSLICED entry that budgeted and unbudgeted
callers share; (d) slicing composes with sharded ``execute_plan``
(slice within shard, zero-nnz shards included); (e) infeasible budgets
raise ``MemoryBudgetError``; (f) a stamped plan replays sliced with no
explicit budget.
"""
import glob
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.autotune import TunerConfig, tune
from repro.core import spec as S
from repro.core import slicing
from repro.core.executor import CSFArrays, dense_oracle, execute_plan
from repro.core.planner import plan
from repro.core.slicing import (MemoryBudgetError, choose_slicing,
                                chunk_footprints, plan_peak_bytes,
                                sliced_execute, stamp_plan_slicing)
from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import from_coords

FAST = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                   warmup=1, repeats=2)


def _inputs(spec, density=0.08, seed=3, fseed=0):
    shape = tuple(spec.dims[i] for i in spec.inputs[0].indices)
    csf = build_csf(random_sparse(shape, density, seed=seed))
    rng = np.random.default_rng(fseed)
    factors = {t.name: jnp.asarray(rng.standard_normal(
                   tuple(spec.dims[i] for i in t.indices))
                   .astype(np.float32))
               for t in spec.inputs if not t.is_sparse}
    return csf, factors


# --------------------------------------------------------------------- #
# (a) exactness: sliced == unsliced to 1e-5, tails included
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec,kind", [
    (S.mttkrp(30, 14, 10, 20), "output"),       # 20 % chunks -> tail
    (S.ttmc3(24, 12, 10, 14, 6), "output"),
    (S.tttp3(24, 12, 10, 18), "contracted"),    # output sparse: r summed
])
def test_sliced_parity_with_tails(spec, kind):
    csf, factors = _inputs(spec)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    arrays = CSFArrays.from_csf(csf)
    full = np.asarray(execute_plan(p, arrays, factors))

    peak = plan_peak_bytes(spec, p.path, p.order, csf.nnz_levels())
    budget = peak // 2
    stamped = stamp_plan_slicing(p, csf.nnz_levels(), budget)
    assert stamped.slice_chunks > 1
    d = slicing.plan_decision(stamped, csf.nnz_levels())
    assert d.kind == kind

    out = np.asarray(execute_plan(p, arrays, factors,
                                  memory_budget=budget))
    np.testing.assert_allclose(out, full, atol=1e-5)
    if not spec.output_is_sparse:
        # and against the dense einsum oracle, not just ourselves
        oracle = dense_oracle(spec, csf, {k: np.asarray(v)
                                          for k, v in factors.items()})
        np.testing.assert_allclose(out, oracle, atol=1e-3)


def test_sliced_parity_pallas_interpret():
    """The chunk executors honor the plan's engine: a Pallas plan replays
    its chunks through the generated kernels (interpret mode on CPU)."""
    import dataclasses
    spec = S.mttkrp(24, 12, 10, 16)
    csf, factors = _inputs(spec)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    arrays = CSFArrays.from_csf(csf)
    full = np.asarray(execute_plan(p, arrays, factors))
    peak = plan_peak_bytes(spec, p.path, p.order, csf.nnz_levels())
    pp = dataclasses.replace(p, backend="pallas", block=8)
    out = execute_plan(pp, arrays, factors, memory_budget=peak // 2,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out), full, atol=1e-5)


def test_zero_nnz_operand_slices_to_zeros():
    spec = S.mttkrp(16, 8, 6, 12)
    csf = build_csf(from_coords(np.zeros((0, 3), dtype=np.int32),
                                np.zeros((0,), dtype=np.float32),
                                (16, 8, 6)))
    rng = np.random.default_rng(0)
    factors = {"B": rng.standard_normal((8, 12)).astype(np.float32),
               "C": rng.standard_normal((6, 12)).astype(np.float32)}
    p = plan(spec)
    peak = plan_peak_bytes(spec, p.path, p.order, csf.nnz_levels())
    stamped = stamp_plan_slicing(p, csf.nnz_levels(), peak // 2)
    assert stamped.slice_chunks > 1
    out = np.asarray(sliced_execute(stamped, CSFArrays.from_csf(csf),
                                    factors))
    assert out.shape == (16, 12) and not out.any()


# --------------------------------------------------------------------- #
# (b) budget compliance: every chunk (tail included) prices under it
# --------------------------------------------------------------------- #
def test_every_chunk_footprint_under_budget():
    spec = S.mttkrp(30, 14, 10, 20)
    csf, _ = _inputs(spec)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    levels = csf.nnz_levels()
    peak = plan_peak_bytes(spec, p.path, p.order, levels)
    for frac in (2, 3, 5):
        budget = peak // frac
        d = choose_slicing(spec, p.path, p.order, levels, budget)
        assert d.chunks > 1 and d.chunk_bytes <= budget < d.peak_bytes
        stamped = stamp_plan_slicing(p, levels, budget)
        fps = chunk_footprints(stamped, levels)
        assert len(fps) == stamped.slice_chunks
        assert max(fps) <= budget

    # an in-budget plan is left alone — no stamp, no slicing
    assert stamp_plan_slicing(p, levels, peak + 1) is p
    d = choose_slicing(spec, p.path, p.order, levels, peak + 1)
    assert (d.mode, d.chunks, d.kind) == (None, 1, "none")


def test_fewest_chunks_rule_prefers_output_mode():
    """MTTKRP's only dense mode is the rank: the decision must pick it,
    as an output mode, with the minimal chunk count (bisection exact —
    chunks-1 must NOT fit)."""
    spec = S.mttkrp(64, 32, 16, 32)
    csf, _ = _inputs(spec, density=0.05, seed=0)
    levels = csf.nnz_levels()
    p = plan(spec, nnz_levels=levels)
    budget = plan_peak_bytes(spec, p.path, p.order, levels) // 2
    d = choose_slicing(spec, p.path, p.order, levels, budget)
    assert d.mode == "a" and d.kind == "output"
    narrower = dict(spec.dims, a=-(-spec.dims["a"] // (d.chunks - 1)))
    assert slicing._footprint(spec, p.path, p.order, levels, narrower,
                              slicing.DEFAULT_ITEMSIZE) > budget


# --------------------------------------------------------------------- #
# (c) one cached plan: the entry is unsliced; budgets share it
# --------------------------------------------------------------------- #
def test_budgeted_tune_caches_one_unsliced_plan(tmp_path):
    spec = S.mttkrp(32, 24, 16, 16)
    csf, factors = _inputs(spec)
    levels = csf.nnz_levels()

    # the model path stamps too: plan(memory_budget=...) returns sliced
    probe = plan(spec, nnz_levels=levels)
    probe_budget = plan_peak_bytes(spec, probe.path, probe.order,
                                   levels) // 2
    assert plan(spec, nnz_levels=levels,
                memory_budget=probe_budget).slice_chunks > 1

    tuned0, s0 = tune(spec, csf=csf, factors=factors,
                      cache_dir=str(tmp_path), tuner=FAST)
    assert not s0.cache_hit and tuned0.slice_chunks == 1
    budget = plan_peak_bytes(spec, tuned0.path, tuned0.order, levels) // 2

    # a budgeted call hits the SAME entry and stamps after the get
    tuned, s1 = tune(spec, csf=csf, factors=factors,
                     cache_dir=str(tmp_path), tuner=FAST,
                     memory_budget=budget)
    assert s1.cache_hit and tuned.slice_chunks > 1
    assert (tuned.path, tuned.order) == (tuned0.path, tuned0.order)

    entries = glob.glob(os.path.join(str(tmp_path), "plan-*.json"))
    assert len(entries) == 1
    with open(entries[0]) as f:
        doc = json.load(f)["plan"]
    assert doc["slice_mode"] is None and doc["slice_chunks"] == 1

    # the chunks all replay the one schedule, exactly
    out = np.asarray(execute_plan(tuned, CSFArrays.from_csf(csf), factors))
    ref = np.asarray(execute_plan(tuned0, CSFArrays.from_csf(csf),
                                  factors))
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_sliced_execute_builds_one_executor_per_width():
    spec = S.mttkrp(24, 12, 10, 10)   # 10 into 3 chunks: widths 4, 4, 2
    csf, factors = _inputs(spec)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    cache = {}
    out = sliced_execute(p, CSFArrays.from_csf(csf), factors,
                         mode="a", chunks=3, executor_cache=cache)
    assert sorted(cache) == [2, 4]     # tail width compiled once, reused
    full = np.asarray(execute_plan(p, CSFArrays.from_csf(csf), factors))
    np.testing.assert_allclose(np.asarray(out), full, atol=1e-5)


# --------------------------------------------------------------------- #
# (d) composes with sharded operands: slice within shard
# --------------------------------------------------------------------- #
def test_sharded_execute_slices_within_shards():
    spec = S.mttkrp(32, 24, 16, 16)
    csf, factors = _inputs(spec, density=0.05, seed=5)
    coo = random_sparse((32, 24, 16), 0.05, seed=5)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    full = np.asarray(execute_plan(p, CSFArrays.from_csf(csf), factors))

    # shard by mode-0 halves, plus one shard with ZERO nonzeros
    mask = coo.coords[:, 0] < 16
    shards = [CSFArrays.from_csf(build_csf(from_coords(
                  coo.coords[m], coo.values[m], coo.shape)))
              for m in (mask, ~mask)]
    shards.append(CSFArrays.from_csf(build_csf(from_coords(
        np.zeros((0, 3), dtype=np.int32),
        np.zeros((0,), dtype=np.float32), coo.shape))))

    peak = plan_peak_bytes(spec, p.path, p.order, csf.nnz_levels())
    out = np.asarray(execute_plan(p, shards, factors,
                                  memory_budget=peak // 2))
    np.testing.assert_allclose(out, full, atol=1e-5)


# --------------------------------------------------------------------- #
# (e) infeasible budgets fail loudly and point at sharding
# --------------------------------------------------------------------- #
def test_infeasible_budget_raises():
    spec = S.mttkrp(32, 24, 16, 16)
    csf, _ = _inputs(spec)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    with pytest.raises(MemoryBudgetError, match="shard"):
        choose_slicing(spec, p.path, p.order, csf.nnz_levels(), 64)
    with pytest.raises(ValueError, match="positive"):
        choose_slicing(spec, p.path, p.order, csf.nnz_levels(), 0)


def test_sliced_execute_rejects_bad_modes():
    spec = S.mttkrp(16, 8, 6, 8)
    csf, factors = _inputs(spec)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    arrays = CSFArrays.from_csf(csf)
    with pytest.raises(ValueError, match="use execute_plan"):
        sliced_execute(p, arrays, factors)           # unstamped plan
    with pytest.raises(ValueError, match="sparse index"):
        sliced_execute(p, arrays, factors, mode="i", chunks=2)
    with pytest.raises(ValueError, match="not in spec dims"):
        sliced_execute(p, arrays, factors, mode="q", chunks=2)


# --------------------------------------------------------------------- #
# (f) a stamped plan replays sliced with no budget in sight
# --------------------------------------------------------------------- #
def test_stamped_plan_replays_sliced(monkeypatch):
    spec = S.mttkrp(24, 12, 10, 16)
    csf, factors = _inputs(spec)
    levels = csf.nnz_levels()
    p = plan(spec, nnz_levels=levels)
    budget = plan_peak_bytes(spec, p.path, p.order, levels) // 2
    stamped = stamp_plan_slicing(p, levels, budget)
    assert stamped.slice_chunks > 1 and p.slice_chunks == 1  # pure stamp

    calls = []
    real = slicing.sliced_execute
    monkeypatch.setattr(slicing, "sliced_execute",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    full = np.asarray(execute_plan(p, CSFArrays.from_csf(csf), factors))
    assert calls == []                       # unstamped: direct path
    out = np.asarray(execute_plan(stamped, CSFArrays.from_csf(csf),
                                  factors))
    assert calls == [1]                      # stamped: sliced path
    np.testing.assert_allclose(out, full, atol=1e-5)
