"""Hypothesis properties of the static plan verifier (DESIGN.md §11).

Two properties pin the verifier to the engines from both sides:

1. **Planner closure** — every plan the planner emits, across the
   enumerated min-depth paths and valid loop orders of all four paper
   kernels, verifies clean.  The verifier never rejects a schedule the
   repo itself produced.
2. **Mutation soundness** — a random single-field mutation of a legal
   plan either (a) still verifies clean AND executes to the oracle
   answer, or (b) is rejected with an error diagnostic.  There is no
   third state: "verifier-accepted but the engine crashes or
   miscomputes" is the bug class this file exists to rule out.

Skipped wholesale where hypothesis is not installed (the CI full lane
has it; minimal local envs may not).
"""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.analysis import verify_plan
from repro.core import spec as S
from repro.core.executor import CSFArrays, dense_oracle, execute_plan
from repro.core.loopnest import enumerate_orders
from repro.core.paths import min_depth_paths
from repro.core.planner import plan as make_plan
from repro.sparse import build_csf, random_sparse

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

SPECS = {
    "mttkrp": S.mttkrp(6, 5, 4, 3),
    "ttmc3": S.ttmc3(5, 4, 3, 3, 2),
    "tttp3": S.tttp3(5, 4, 3, 3),
    "tttc6": S.tttc6(3, 2),
}


def _inputs_for(spec, seed=0):
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, 0.3, seed=seed))
    rng = np.random.default_rng(seed)
    factors = {t.name: rng.standard_normal(
                   [spec.dims[i] for i in t.indices]).astype(np.float32)
               for t in spec.inputs if not t.is_sparse}
    return csf, factors


# --------------------------------------------------------------------- #
# (1) planner closure: enumerated nests all verify clean
# --------------------------------------------------------------------- #
@settings(max_examples=30, deadline=None)
@given(name=st.sampled_from(sorted(SPECS)),
       path_i=st.integers(0, 5), order_i=st.integers(0, 5))
def test_enumerated_nests_verify_clean(name, path_i, order_i):
    spec = SPECS[name]
    paths = list(itertools.islice(
        min_depth_paths(spec, max_paths=path_i + 1, slack=1), path_i + 1))
    path = paths[path_i % len(paths)]
    orders = list(itertools.islice(
        enumerate_orders(path, spec.sparse_indices), order_i + 1))
    order = orders[order_i % len(orders)]
    rep = verify_plan(spec, path, order)
    assert rep.ok, f"{name}: verifier rejected an enumerated nest: " \
                   f"{[str(d) for d in rep.errors]}"


# --------------------------------------------------------------------- #
# (2) mutation soundness: accepted -> executes; otherwise diagnosed
# --------------------------------------------------------------------- #
# one (field, value) pool per mutable plan axis; values mix legal and
# illegal deliberately — the property holds for both
_MUTATIONS = st.one_of(
    st.tuples(st.just("backend"),
              st.sampled_from(["reference", "xla", "pallas", "tpu", ""])),
    st.tuples(st.just("fused"), st.booleans()),
    st.tuples(st.just("block"), st.sampled_from([0, 8, 16, 24, 100, -8])),
    st.tuples(st.just("slice_mode"),
              st.sampled_from([None, "a", "i", "q"])),
    st.tuples(st.just("slice_chunks"),
              st.sampled_from([0, 1, 2, 3, 10**6])),
    st.tuples(st.just("mesh"),
              st.sampled_from([None, {"mesh_shape": 3}])),
)


@settings(max_examples=40, deadline=None)
@given(mutation=_MUTATIONS)
def test_single_field_mutation_is_sound(mutation):
    field, value = mutation
    spec = SPECS["mttkrp"]
    base = make_plan(spec)
    mutated = dataclasses.replace(base, **{field: value})
    rep = verify_plan(mutated)
    if not rep.ok:
        # rejected plans carry at least one error diagnostic with a
        # stable code and a stage_ref pointing at the mutated axis
        assert rep.errors
        assert all(d.code.startswith("SPTTN-E") for d in rep.errors)
        return
    # verifier accepted: the engines must run it and agree with the
    # oracle — anything else is the accepted-but-crashes bug class
    csf, factors = _inputs_for(spec)
    kwargs = {"interpret": True} if mutated.backend == "pallas" else {}
    out = execute_plan(mutated, CSFArrays.from_csf(csf), factors, **kwargs)
    oracle = np.asarray(dense_oracle(spec, csf, factors), dtype=np.float64)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float64), oracle,
                               rtol=1e-3, atol=1e-3)
