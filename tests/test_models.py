"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_reduced, make_batch
from repro.configs.base import RunConfig
from repro.models import (decode_step, forward, loss_fn,
                          model_init, prefill)
from repro.models.transformer import _encode
from repro.train.train_step import init_train_state, make_train_step


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params, specs = model_init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, "train_4k", batch_override=2, seq_override=32)
    logits, aux = forward(params, cfg, batch, remat=False)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    run = RunConfig(model=cfg, remat=False, learning_rate=1e-3)
    step = make_train_step(cfg, run)
    state = init_train_state(params)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params, _ = model_init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, "train_4k", batch_override=B, seq_override=S)
    logits_full, _ = forward(params, cfg, batch, remat=False)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : S - 1]
    last_logits, caches = prefill(params, cfg, pre_batch, cache_len=S)
    enc_out = None
    if cfg.encdec:
        enc_out = _encode(params, cfg,
                          batch["enc_frames"].astype(cfg.compute_dtype),
                          remat=False)
    step_logits, _ = decode_step(params, cfg, caches,
                                 batch["tokens"][:, S - 1: S],
                                 jnp.asarray(S - 1, jnp.int32),
                                 enc_out=enc_out)
    # MoE capacity effects allow a slightly looser tolerance
    tol = 5e-2 if cfg.moe is not None else 5e-4
    np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                               np.asarray(logits_full[:, S - 2]), atol=tol)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(logits_full[:, S - 1]), atol=tol)


@pytest.mark.slow
def test_microbatch_equivalence():
    """k microbatches must match the single-batch gradient step."""
    cfg = get_reduced("smollm-135m")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, "train_4k", batch_override=4, seq_override=16)
    s1, m1 = make_train_step(cfg, RunConfig(model=cfg, remat=False))(
        init_train_state(params), batch)
    s2, m2 = make_train_step(
        cfg, RunConfig(model=cfg, remat=False, microbatches=2))(
        init_train_state(params), batch)
    # losses may differ (mean over different slices); params must be close
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_remat_matches_no_remat():
    cfg = get_reduced("olmo-1b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, "train_4k", batch_override=2, seq_override=16)
    l1, _ = loss_fn(params, cfg, batch, remat=False)
    l2, _ = loss_fn(params, cfg, batch, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_long_context_flags():
    from repro.configs import get_config, shape_applicable
    runs = {a: shape_applicable(get_config(a), "long_500k")[0]
            for a in ARCHS}
    assert runs["rwkv6-3b"] and runs["recurrentgemma-9b"]
    assert runs["gemma3-1b"]       # 5:1 local:global — mostly windowed
    assert not runs["qwen1.5-32b"] and not runs["olmo-1b"]
    assert not runs["deepseek-v2-236b"]
