"""Serving-path gates (DESIGN.md §9): continuous-batching correctness
regressions, the plan-cache hot-path tiers, bucketed-reuse guard + parity,
batched CSF construction, and the bench-gate seeding rule.

Unlike test_sparse.py these tests carry no hypothesis dependency — the
serving regressions must run everywhere tier-1 runs.
"""
import importlib.util
import json
import os
import threading

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# Server loop regressions
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.configs import get_reduced
    from repro.models import model_init
    cfg = get_reduced("smollm-135m")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_server_max_new_one_not_dropped(small_model):
    """Regression: a request admitted and finished within one step used to
    be silently dropped (run() snapshotted active before the refill)."""
    from repro.serve.serve_step import Request, Server
    cfg, params = small_model
    srv = Server(cfg, params, slots=2, cache_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new=1) for _ in range(3)]
    for r in reqs:
        srv.submit(r)
    done = srv.run(max_steps=16)
    assert len(done) == 3
    assert all(r.done and len(r.out) == 1 for r in reqs)


def test_server_mixed_length_parity(small_model):
    """Regression: decode used one shared max() position, so the shorter
    of two mixed-length prompts attended at the wrong cache rows."""
    from repro.serve.serve_step import Request, Server
    cfg, params = small_model
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab, 3).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 11).astype(np.int32)

    def solo(prompt):
        srv = Server(cfg, params, slots=2, cache_len=32)
        srv.submit(Request(prompt=prompt, max_new=6))
        (req,) = srv.run(max_steps=32)
        return req.out

    ra, rb = solo(pa), solo(pb)
    srv = Server(cfg, params, slots=2, cache_len=32)
    qa = Request(prompt=pa, max_new=6)
    qb = Request(prompt=pb, max_new=6)
    srv.submit(qa)
    srv.submit(qb)
    done = srv.run(max_steps=32)
    assert len(done) == 2
    assert qa.out == ra
    assert qb.out == rb


def test_server_prompt_bound_check(small_model):
    from repro.serve.serve_step import Request, Server
    cfg, params = small_model
    srv = Server(cfg, params, slots=1, cache_len=16)
    with pytest.raises(ValueError, match="cache_len"):
        srv.submit(Request(prompt=np.zeros(17, np.int32)))


# --------------------------------------------------------------------------- #
# Plan-cache hot path
# --------------------------------------------------------------------------- #
def _routing(N, E, k, C, seed):
    from repro.serve import moe_routing_coo
    r = np.random.default_rng(seed)
    idx = np.argsort(-r.standard_normal((N, E)), axis=1)[:, :k]
    return moe_routing_coo(idx, E, C)


def _service(cache_dir, bucket="log2", **kw):
    from repro.autotune.tuner import TunerConfig
    from repro.serve import PlanService
    cfg = TunerConfig(profile_bucket=bucket, max_paths=2, max_candidates=2,
                      orders_per_path=1, warmup=0, repeats=1, **kw)
    return PlanService(cache_dir=cache_dir, config=cfg)


N, E, K, C, D = 32, 4, 2, 16, 16


def test_plan_service_cache_kinds(tmp_path, monkeypatch):
    """cold -> bucket -> exact tiers, observed through PlanCache.get/put."""
    from repro.autotune.cache import PlanCache
    calls = {"get": 0, "put": 0}
    real_get, real_put = PlanCache.get, PlanCache.put
    monkeypatch.setattr(PlanCache, "get", lambda self, key: (
        calls.__setitem__("get", calls["get"] + 1) or real_get(self, key)))
    monkeypatch.setattr(PlanCache, "put", lambda self, key, plan, meta=None: (
        calls.__setitem__("put", calls["put"] + 1)
        or real_put(self, key, plan, meta=meta)))

    svc = _service(str(tmp_path))
    x = np.random.default_rng(0).standard_normal((N, D)).astype(np.float32)

    _, st = svc.dispatch(_routing(N, E, K, C, 0), x)
    assert st.kind == "cold"
    assert calls["put"] == 2        # persisted under exact AND bucketed key
    # a perturbed pattern: in-memory bucket tier, no further disk traffic
    gets_before = calls["get"]
    _, st = svc.dispatch(_routing(N, E, K, C, 1), x)
    assert st.kind == "bucket"
    assert calls["get"] == gets_before
    # the same pattern again: exact in-memory hit
    _, st = svc.dispatch(_routing(N, E, K, C, 1), x)
    assert st.kind == "exact"

    # a FRESH service over the same disk cache: the tuner's disk tiers
    svc2 = _service(str(tmp_path))
    _, st = svc2.dispatch(_routing(N, E, K, C, 0), x)
    assert st.kind == "exact"       # exact disk entry from the cold search
    _, st = svc2.dispatch(_routing(N, E, K, C, 2), x)
    assert st.kind == "bucket"      # bucketed disk entry, guard admitted


def test_bucket_hit_parity_vs_fresh_tune(tmp_path):
    """Acceptance: bucket-hit execution matches a freshly tuned plan 1e-5."""
    x = np.random.default_rng(1).standard_normal((N, D)).astype(np.float32)
    svc = _service(str(tmp_path / "bucketed"))
    svc.dispatch(_routing(N, E, K, C, 0), x)          # pays the search
    fresh = _service(str(tmp_path / "fresh"), bucket=None)
    for seed in range(1, 5):
        coo = _routing(N, E, K, C, seed)
        out, st = svc.dispatch(coo, x)
        assert st.kind in ("bucket", "exact")
        ref, fst = fresh.dispatch(coo, x)
        assert fst.kind in ("cold", "exact")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        # and both match the dense einsum oracle
        np.testing.assert_allclose(
            np.asarray(out),
            np.einsum("tec,td->ecd", coo.to_dense(), x), atol=1e-4)


def test_budgeted_service_slices_dispatch(tmp_path):
    """A service built with memory_budget dispatches over-budget plans
    through the sliced replay path, exactly, reusing one chunk-executor
    set per plan across requests — and shares the budget-free plan cache
    with unbudgeted services."""
    from repro.autotune.tuner import TunerConfig
    from repro.serve import PlanService
    cfg = TunerConfig(profile_bucket="log2", max_paths=2, max_candidates=2,
                      orders_per_path=1, warmup=0, repeats=1)
    x = np.random.default_rng(3).standard_normal((N, D)).astype(np.float32)

    plain = PlanService(cache_dir=str(tmp_path), tuner=cfg)
    ref, st = plain.dispatch(_routing(N, E, K, C, 0), x)
    assert st.kind == "cold"

    budgeted = PlanService(cache_dir=str(tmp_path), tuner=cfg,
                           memory_budget=4096)
    out, st = budgeted.dispatch(_routing(N, E, K, C, 0), x)
    assert st.kind == "exact"       # same disk entry the cold search wrote
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # the dispatch really went through chunk executors, and repeats reuse
    assert len(budgeted._chunk_executors) == 1
    widths = next(iter(budgeted._chunk_executors.values()))
    assert widths and all(isinstance(w, int) for w in widths)
    out2, _ = budgeted.dispatch(_routing(N, E, K, C, 0), x)
    assert len(budgeted._chunk_executors) == 1
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               atol=1e-5)


def test_bucket_guard_forces_replan(tmp_path):
    """A bucketed entry whose cost estimate fails the tolerance must be
    ignored — the request replans instead of running a foreign nest."""
    from repro.sparse import build_csf
    x = np.random.default_rng(2).standard_normal((N, D)).astype(np.float32)
    svc = _service(str(tmp_path))
    svc.dispatch(_routing(N, E, K, C, 0), x)
    # zero tolerance: every bucketed estimate exceeds it
    svc_strict = _service(str(tmp_path), bucket_tolerance=1e-9)
    _, st = svc_strict.dispatch(_routing(N, E, K, C, 1), x)
    assert st.kind == "cold"


def test_plan_cache_two_writer_race(tmp_path):
    """Atomic publish claim: concurrent put() under one key never leaves a
    torn entry — get() always parses a complete plan."""
    from repro.autotune.cache import PlanCache
    from repro.core.planner import plan
    from repro.core import spec as S
    p1 = plan(S.mttkrp(8, 6, 5, 4))
    p2 = plan(S.mttkrp(8, 6, 5, 4), nnz_levels={0: 1, 1: 8, 2: 24, 3: 48})
    cache = PlanCache(str(tmp_path))
    errs = []

    def writer(p, n):
        try:
            for _ in range(n):
                cache.put("contended", p, meta={"w": id(p)})
        except Exception as e:          # pragma: no cover - fail loudly
            errs.append(e)

    ts = [threading.Thread(target=writer, args=(p, 25)) for p in (p1, p2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    got = cache.get("contended")
    assert got is not None and got.path in (p1.path, p2.path)
    # the entry on disk is complete, valid JSON
    with open(cache._path("contended")) as f:
        doc = json.load(f)
    assert doc["cache_version"] == __import__(
        "repro.autotune.cache", fromlist=["CACHE_VERSION"]).CACHE_VERSION


def test_build_csf_batch_matches_sequential():
    from repro.sparse import build_csf, build_csf_batch
    from repro.sparse.coo import random_sparse
    from repro.sparse.coo import COOTensor
    coos = [random_sparse((8, 9, 10), d, seed=s)
            for s, d in enumerate([0.05, 0.2, 0.01, 0.5])]
    # an empty member mid-batch must round-trip too
    coos.insert(2, COOTensor(coords=np.zeros((0, 3), np.int32),
                             values=np.zeros(0, np.float32),
                             shape=(8, 9, 10)))
    batch = build_csf_batch(coos)
    assert len(batch) == len(coos)
    for c, b in zip(coos, batch):
        ref = build_csf(c)
        assert ref.nfib == b.nfib
        for p in ref.coord:
            np.testing.assert_array_equal(ref.coord[p], b.coord[p])
            np.testing.assert_array_equal(ref.parent[p], b.parent[p])
            np.testing.assert_array_equal(ref.seg[p], b.seg[p])


def test_bucketed_key_collapses_perturbed_profiles():
    from repro.autotune.cache import (bucket_nnz_levels, bucketed_cache_key,
                                      cache_key)
    from repro.core import spec as S
    spec = S.mttkrp(8, 6, 5, 4)
    a = {0: 1, 1: 8, 2: 20, 3: 40}
    b = {0: 1, 1: 8, 2: 22, 3: 37}
    assert cache_key(spec, a, "cpu:x") != cache_key(spec, b, "cpu:x")
    assert (bucketed_cache_key(spec, a, "cpu:x")
            == bucketed_cache_key(spec, b, "cpu:x"))
    # the bucketed key can never collide with an exact key over the same
    # (already-bucketed) profile: the scheme is part of the hashed doc
    ab = bucket_nnz_levels(a)
    assert bucketed_cache_key(spec, a, "cpu:x") != cache_key(
        spec, ab, "cpu:x")


# --------------------------------------------------------------------------- #
# Bench-gate seeding rule
# --------------------------------------------------------------------------- #
def test_bench_regression_new_rows_non_gating(capsys):
    """A row present only in the new medians (e.g. the serve-latency rows
    on their first appearance) is reported but never fails the gate."""
    spec = importlib.util.spec_from_file_location(
        "check_bench_regression",
        os.path.join(REPO, "scripts", "check_bench_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    base = {"mttkrp": {"uniform-3d|xla": 100.0}}
    new = {"mttkrp": {"uniform-3d|xla": 110.0},
           "serve_latency": {"serve|cold-miss": 313748.9,
                             "serve|bucket-hit": 5473.5}}
    assert mod.compare(base, new, threshold=3.0) == 0
    out = capsys.readouterr().out
    assert out.count("NEW (non-gating)") == 2
    # ... while a genuine regression on a shared row still fails
    worse = {"mttkrp": {"uniform-3d|xla": 400.0}}
    assert mod.compare(base, worse, threshold=3.0) == 1
