"""User-docs gates in tier-1 (mirrored by the CI docs lane).

Every ``>>>`` example in README.md and docs/ must execute verbatim, the
public-API docstring examples must run, and no markdown file may carry a
broken intra-repo link.  CI runs the same checks standalone
(``pytest --doctest-glob='*.md' README.md docs`` +
``scripts/check_doc_links.py``), so a docs regression fails both lanes.
"""
import doctest
import importlib
import importlib.util
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FLAGS = doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE

MARKDOWN_WITH_DOCTESTS = [
    "README.md",
    "docs/architecture.md",
    "docs/plan-format.md",
    "docs/distributed.md",
    "docs/cost-models.md",
    "docs/serving.md",
    "docs/out-of-core.md",
    "docs/analysis.md",
    "docs/backends.md",
]

# the public API surface whose docstrings carry runnable examples
API_MODULES = [
    "repro.core.spec",
    "repro.core.planner",
    "repro.core.executor",
    "repro.core.cost",
    "repro.core.order_dp",
    "repro.core.slicing",
    "repro.autotune.cache",
    "repro.autotune.tuner",
    "repro.distributed.spttn_dist",
]


@pytest.mark.parametrize("relpath", MARKDOWN_WITH_DOCTESTS)
def test_markdown_examples_run(relpath):
    res = doctest.testfile(os.path.join(REPO, relpath),
                           module_relative=False, optionflags=FLAGS)
    assert res.attempted > 0, f"{relpath} lost its examples"
    assert res.failed == 0, f"{relpath}: {res.failed} failing example(s)"


@pytest.mark.parametrize("modname", API_MODULES)
def test_api_docstring_examples_run(modname):
    mod = importlib.import_module(modname)
    res = doctest.testmod(mod, optionflags=FLAGS)
    assert res.attempted > 0, f"{modname} lost its docstring examples"
    assert res.failed == 0, f"{modname}: {res.failed} failing example(s)"


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO, "scripts", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_no_broken_intra_repo_links(capsys):
    mod = _load_script("check_doc_links")
    assert mod.main(["check_doc_links.py", REPO]) == 0, capsys.readouterr().out


def test_examples_use_facade_imports(capsys):
    """Mirror of the CI example-import lint: examples are the copy-paste
    surface, so they must import through the `repro` facade, not the
    implementation packages it re-exports."""
    mod = _load_script("check_example_imports")
    assert mod.main(["check_example_imports.py", REPO]) == 0, \
        capsys.readouterr().out


def test_every_doc_is_registered(capsys):
    """Mirror of the CI docs-registration lint: a docs/*.md added without
    an entry in MARKDOWN_WITH_DOCTESTS would never have its examples run,
    so it fails here and in the docs lane."""
    mod = _load_script("check_docs_registered")
    assert mod.main(["check_docs_registered.py", REPO]) == 0, \
        capsys.readouterr().out
    # the script reads the same registry this module executes
    assert set(mod.registered_docs(REPO)) == set(MARKDOWN_WITH_DOCTESTS)
