"""Pad-to-tile lowering pass (DESIGN.md §8) + the tuned block axis.

(a) Shape inspection: with ``tile_align=True`` every generated stage —
row, segsum, and fused-chain — has a block that is a multiple of the
TPU sublane tile (8) and operand/output lane widths padded to 128, the
tile-legality precondition for ``interpret=False`` on real TPUs.
(b) The pass is value-preserving: interpret-mode parity vs the
Algorithm-2 reference at 1e-5 on MTTKRP/TTMc/TTTP, including the edge
cases (dims already lane-aligned, dims far below one tile, zero-nnz
padded tails).
(c) ``block`` is an autotuning axis: candidates expand across the
grid, the winner's block persists in plan JSON v5 (v4 rejected by the
loader and the cache), and ``execute_plan`` / ``make_distributed_tuned``
replay it.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.autotune import TunerConfig, generate_candidates, tune
from repro.autotune.cache import CACHE_VERSION, PlanCache, cache_key
from repro.core import spec as S
from repro.core.executor import (PLAN_JSON_VERSION, CSFArrays,
                                 dense_oracle, execute_plan,
                                 plan_from_dict, plan_to_dict,
                                 reference_execute)
from repro.core.planner import plan
from repro.kernels.codegen import (TILE_LANE, TILE_SUBLANE,
                                   PallasPlanExecutor, lane_pad)
from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import from_coords


def _factors(spec, rng, dtype=np.float32):
    return {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(dtype)
        for t in spec.inputs if not t.is_sparse}


def _densify(spec, csf, out):
    if not spec.output_is_sparse:
        return np.asarray(out)
    dense = np.zeros([spec.dims[i] for i in spec.output.indices])
    dense[tuple(csf.coo.coords.T)] = np.asarray(out)
    return dense


def _assert_tile_aligned(ex):
    """Every stage the executor emitted satisfies the TPU tile rules."""
    assert ex.emitted_stages, "executor emitted no stages to inspect"
    assert ex.block % TILE_SUBLANE == 0
    for st in ex.emitted_stages:
        assert st.tile
        assert st.block % TILE_SUBLANE == 0
        assert st.out_pad % TILE_LANE == 0
        for op in st.operands:
            assert st.op_pad(op) % TILE_LANE == 0
    for _, links in ex.emitted_chains:
        for link in links:
            for op in link.operands:
                assert lane_pad(op.flat_dim) % TILE_LANE == 0


# --------------------------------------------------------------------- #
# (a)+(b) tile-aligned specs for all three stage kinds, interpret parity
# --------------------------------------------------------------------- #
TILE_KERNELS = [
    pytest.param(S.mttkrp(6, 7, 8, 4), 0.3, id="mttkrp"),
    pytest.param(S.ttmc3(6, 7, 8, 4, 3), 0.3, id="ttmc"),
    pytest.param(S.tttp3(6, 7, 8, 4), 0.3, id="tttp"),
]


@pytest.mark.parametrize("spec,density", TILE_KERNELS)
@pytest.mark.parametrize("strategy", ["row", "segsum"])
def test_tile_aligned_stages_match_reference(spec, density, strategy):
    rng = np.random.default_rng(1)
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, density, seed=3))
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    ex = PallasPlanExecutor(spec, p.path, p.order, block=16, interpret=True,
                            strategy=strategy, tile_align=True)
    out = _densify(spec, csf, ex(arrays, factors))
    np.testing.assert_allclose(out, ref, atol=1e-5, err_msg=str(spec))
    _assert_tile_aligned(ex)


def test_tile_aligned_fused_chain_matches_reference():
    """The fused-chain kind: one kernel, every level's buffer and link
    operand lane-padded, same answer."""
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    rng = np.random.default_rng(0)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True,
                            strategy="fused", tile_align=True)
    np.testing.assert_allclose(np.asarray(ex(arrays, factors)), ref,
                               atol=1e-5)
    assert "fused" in ex.stage_strategy.values()
    assert ex.emitted_chains          # the chain stage really was emitted
    _assert_tile_aligned(ex)


def test_emitted_stages_reset_per_call():
    """A long-lived executor's inspection surface reflects only its
    latest trace — repeated eager calls must not accumulate stages."""
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(random_sparse((6, 7, 8), 0.3, seed=3))
    rng = np.random.default_rng(1)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True,
                            tile_align=True)
    ex(arrays, factors)
    first = (len(ex.emitted_stages), dict(ex.stage_strategy))
    ex(arrays, factors)
    assert (len(ex.emitted_stages), dict(ex.stage_strategy)) == first


def test_block_forced_to_sublane_multiple_only_in_tile_mode():
    spec = S.mttkrp(6, 7, 8, 4)
    p = plan(spec)
    tiled = PallasPlanExecutor(spec, p.path, p.order, block=5,
                               interpret=True, tile_align=True)
    assert tiled.block == 8
    loose = PallasPlanExecutor(spec, p.path, p.order, block=5,
                               interpret=True, tile_align=False)
    assert loose.block == 5           # interpret mode keeps the request
    with pytest.raises(ValueError, match="block must be positive"):
        PallasPlanExecutor(spec, p.path, p.order, block=0, interpret=True)


def test_tile_align_defaults_to_compiled_mode():
    """tile_align=None resolves to (not interpret): interpret-mode
    validation stays unpadded, compiled mode gets the pass."""
    spec = S.mttkrp(6, 7, 8, 4)
    p = plan(spec)
    ex = PallasPlanExecutor(spec, p.path, p.order, interpret=True)
    assert ex.tile_align is False
    ex = PallasPlanExecutor(spec, p.path, p.order, interpret=False,
                            tile_align=None)
    assert ex.tile_align is True


# --------------------------------------------------------------------- #
# (b) edge cases
# --------------------------------------------------------------------- #
def test_already_lane_aligned_dims_pad_nothing():
    """R=128: flattened dense widths are already lane multiples, so the
    pass is a no-op on widths (and still exact)."""
    spec = S.mttkrp(6, 5, 4, 128)
    csf = build_csf(random_sparse((6, 5, 4), 0.3, seed=2))
    rng = np.random.default_rng(1)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True,
                            tile_align=True)
    out = np.asarray(ex(CSFArrays.from_csf(csf), factors))
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-4)
    _assert_tile_aligned(ex)
    for st in ex.emitted_stages:
        assert st.out_pad == st.out_flat_dim          # no padding added
        for op in st.operands:
            if op.flat_dim % 128 == 0:    # already aligned: no-op
                assert st.op_pad(op) == op.flat_dim
            else:                         # the width-1 values operand
                assert op.flat_dim == 1 and st.op_pad(op) == 128


def test_dims_smaller_than_one_tile():
    """R=3: every lane width pads 3 -> 128; the slices must recover the
    exact 3-wide results."""
    spec = S.mttkrp(6, 7, 8, 3)
    csf = build_csf(random_sparse((6, 7, 8), 0.3, seed=5))
    rng = np.random.default_rng(4)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True,
                            tile_align=True)
    out = np.asarray(ex(CSFArrays.from_csf(csf), factors))
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-5)
    for st in ex.emitted_stages:
        assert st.out_pad == 128 or st.out_flat_dim % 128 == 0


def test_single_nnz_padded_tail_contributes_zero():
    """One nonzero in a block of 8: the 7 pad slots gather nonzero 0's
    values and must be annihilated by the pre-folded mask."""
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(from_coords(np.array([[1, 2, 3]]),
                                np.array([2.0], np.float32), (6, 7, 8)))
    rng = np.random.default_rng(4)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True,
                            tile_align=True)
    fn = jax.jit(lambda f: ex(CSFArrays.from_csf(csf), f))
    out = np.asarray(fn(factors))
    np.testing.assert_allclose(
        out, dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()}),
        atol=1e-5)


def test_zero_nnz_tensor_through_tile_mode():
    """An empty pattern emits no stages and returns exact zeros — the
    degenerate tail of the pad-to-tile path."""
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(from_coords(np.zeros((0, 3), np.int64),
                                np.zeros(0, np.float32), (6, 7, 8)))
    rng = np.random.default_rng(4)
    factors = _factors(spec, rng)
    p = plan(spec)
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True,
                            tile_align=True)
    out = np.asarray(ex(CSFArrays.from_csf(csf), factors))
    assert out.shape == (6, 4)
    np.testing.assert_array_equal(out, 0.0)


# --------------------------------------------------------------------- #
# (c) block as an autotuning axis + plan JSON v5
# --------------------------------------------------------------------- #
def _mttkrp_inputs():
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    rng = np.random.default_rng(0)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    return spec, csf, factors


def test_candidates_expand_across_block_grid():
    spec, csf, _ = _mttkrp_inputs()
    cands = generate_candidates(spec, nnz_levels=csf.nnz_levels(),
                                max_paths=2, max_candidates=2,
                                orders_per_path=1,
                                backends=("xla", "pallas"),
                                blocks=(8, 16))
    assert len({c.key for c in cands}) == len(cands)
    assert {c.block for c in cands if c.backend == "pallas"} == {8, 16}
    assert all(c.block == 0 for c in cands if c.backend == "xla")
    # the grid must be sublane-aligned up front — the pad-to-tile pass
    # cannot repair a misaligned sweep without changing what is measured
    for bad in ((12,), (0,), (-8,), ("128",)):
        with pytest.raises(ValueError, match="multiples of 8"):
            generate_candidates(spec, max_paths=2, max_candidates=1,
                                orders_per_path=1, backends=("pallas",),
                                blocks=bad)


def test_blocks_grid_is_part_of_the_cache_key():
    spec, csf, _ = _mttkrp_inputs()
    levels = csf.nnz_levels()
    default = cache_key(spec, levels, "cpu:x", backends=("pallas",))
    swept = cache_key(spec, levels, "cpu:x", backends=("pallas",),
                      blocks=(8, 16))
    other = cache_key(spec, levels, "cpu:x", backends=("pallas",),
                      blocks=(8,))
    assert len({default, swept, other}) == 3


def test_tuned_block_persists_and_replays(tmp_path):
    """Sweep a two-point block grid under a forced pallas axis: the
    winner's block lands in the plan + cache, survives the disk round
    trip, and execute_plan compiles the replay at exactly that block."""
    spec, csf, factors = _mttkrp_inputs()
    cfg = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                      warmup=1, repeats=2, backends=("pallas",),
                      blocks=(8, 16))
    tuned, stats = tune(spec, csf=csf, factors=factors,
                        cache_dir=str(tmp_path), tuner=cfg)
    assert tuned.backend == "pallas"
    assert tuned.block in (8, 16)
    assert stats.candidates_timed >= 2       # both blocks reached the timer

    # disk round trip: cache hit returns the same block
    tuned2, stats2 = tune(spec, csf=csf, factors=factors,
                          cache_dir=str(tmp_path), tuner=cfg)
    assert stats2.cache_hit and tuned2 == tuned
    assert tuned2.block == tuned.block

    # the meta records every (block, seconds) pair that was measured
    entry = json.loads((tmp_path / f"plan-{stats.cache_key}.json")
                       .read_text())
    assert entry["cache_version"] == CACHE_VERSION == 7
    assert {t["block"] for t in entry["meta"]["timings"]} == {8, 16}

    # execute_plan replays the tuned block on the generated-kernel engine
    seen = {}
    import repro.core.executor as core_exec
    real = core_exec.make_executor

    def spy(spec_, path_, order_, backend="xla", **kw):
        seen.update(kw, backend=backend)
        return real(spec_, path_, order_, backend=backend, **kw)

    core_exec_make, core_exec.make_executor = \
        core_exec.make_executor, spy
    try:
        out = execute_plan(tuned2, CSFArrays.from_csf(csf), factors)
    finally:
        core_exec.make_executor = core_exec_make
    assert seen["backend"] == "pallas" and seen["block"] == tuned.block
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-4)


def test_plan_json_v5_block_round_trip_and_v4_rejection():
    p = plan(S.mttkrp(8, 6, 5, 3))
    tagged = dataclasses.replace(p, backend="pallas", block=24)
    doc = plan_to_dict(tagged)
    assert doc["version"] == PLAN_JSON_VERSION == 6
    assert doc["block"] == 24
    rt = plan_from_dict(doc)
    assert rt == tagged and rt.block == 24
    # v4 documents (no block field, version 4) are rejected outright
    v4 = dict(doc)
    v4.pop("block")
    v4["version"] = 4
    with pytest.raises(ValueError, match="unsupported plan version 4"):
        plan_from_dict(v4)


def test_cache_rejects_v4_stamped_entry(tmp_path):
    """A v4-era cache file restored under a current key name is a clean
    miss — the loader never sees its plan document."""
    cache = PlanCache(str(tmp_path))
    p = plan(S.mttkrp(8, 6, 5, 3))
    path = cache.put("k", p)
    with open(path) as f:
        doc = json.load(f)
    doc["cache_version"] = 4
    doc["plan"]["version"] = 4
    doc["plan"].pop("block", None)
    with open(path, "w") as f:
        json.dump(doc, f)
    assert cache.get("k") is None


def test_distributed_replay_honors_per_shard_block(tmp_path):
    """make_distributed_tuned replays each pallas shard at its tuned
    block (single-device mesh keeps this CPU-runnable)."""
    from jax.sharding import Mesh
    from repro.distributed.spttn_dist import make_distributed_tuned
    spec = S.mttkrp(16, 12, 10, 8)
    T = random_sparse((16, 12, 10), 0.1, seed=2)
    csf = build_csf(T)
    rng = np.random.default_rng(0)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    cfg = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                      warmup=1, repeats=2, backends=("pallas",),
                      blocks=(16,))
    dist = make_distributed_tuned(spec, T, mesh, {0: "data"},
                                  cache_dir=str(tmp_path), tuner=cfg,
                                  prefer_collective=False)
    assert dist.mode == "replay"
    live = [sh for sh in dist.shards if sh.plan is not None]
    assert live and all(sh.plan.backend == "pallas" and sh.plan.block == 16
                        for sh in live)
    single = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, single.path, single.order, csf,
                            {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(dist(factors), ref, atol=1e-4)
    # the stacked route (default) replays the tuned block mesh-wide
    dist2 = make_distributed_tuned(spec, T, mesh, {0: "data"},
                                   cache_dir=str(tmp_path), tuner=cfg)
    assert dist2.mode == "collective-pallas"
    assert dist2.collective.executor.block == 16
    np.testing.assert_allclose(dist2(factors), ref, atol=1e-4)
