"""Training infrastructure: loss goes down, checkpoint/restore resume,
failure injection + elastic re-mesh, data pipeline determinism."""
import os

import numpy as np

import jax

from repro.configs import get_reduced
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM, make_loader
from repro.models import model_init
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train.train_step import init_train_state, make_train_step


def _tiny_setup(seed=0):
    cfg = get_reduced("smollm-135m")
    params, _ = model_init(jax.random.PRNGKey(seed), cfg)
    run = RunConfig(model=cfg, remat=False, learning_rate=3e-3,
                    warmup_steps=5)
    step = jax.jit(make_train_step(cfg, run))
    ds, it = make_loader(cfg.vocab, 16, 4, seed=1)
    return cfg, step, init_train_state(params), ds


def test_loss_decreases():
    cfg, step, state, ds = _tiny_setup()
    losses = []
    for i in range(30):
        state, m = step(state, ds.batch_at(i % 4))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg, step, state, ds = _tiny_setup()
    for i in range(3):
        state, _ = step(state, ds.batch_at(i))
    d = str(tmp_path / "ckpt")
    ckpt.save(state, d, step=3)
    restored, at = ckpt.restore(state, d)
    assert at == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_determinism(tmp_path):
    """train 6 straight == train 3, checkpoint, restore, train 3 more."""
    cfg, step, state, ds = _tiny_setup()
    s_straight = state
    for i in range(6):
        s_straight, _ = step(s_straight, ds.batch_at(i))

    s = state
    for i in range(3):
        s, _ = step(s, ds.batch_at(i))
    d = str(tmp_path / "c")
    ckpt.save(s, d, step=3)
    s2, at = ckpt.restore(s, d)
    for i in range(at, 6):
        s2, _ = step(s2, ds.batch_at(i))
    for a, b in zip(jax.tree.leaves(s_straight.params),
                    jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_prune_and_latest(tmp_path):
    cfg, step, state, ds = _tiny_setup()
    d = str(tmp_path / "c")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(state, d, step=s, keep=2)
    assert ckpt.latest_steps(d) == [4, 5]
    assert ckpt.latest_step(d) == 5


def test_checkpoint_incomplete_ignored(tmp_path):
    cfg, step, state, ds = _tiny_setup()
    d = str(tmp_path / "c")
    ckpt.save(state, d, step=1)
    # a crashed writer: shard present, manifest missing
    bad = os.path.join(d, "step_00000002")
    os.makedirs(bad)
    open(os.path.join(bad, "shard_0.npz"), "wb").write(b"partial")
    assert ckpt.latest_step(d) == 1


def test_failure_injection_end_to_end(tmp_path):
    """Simulated failures mid-run: restore + deterministic data => same
    final params as the uninterrupted run."""
    cfg, step, state, ds = _tiny_setup()
    d = str(tmp_path / "c")
    n_steps = 10
    golden = state
    for i in range(n_steps):
        golden, _ = step(golden, ds.batch_at(i))

    fails = set(fault.simulate_failure_schedule(n_steps, mtbf_steps=3,
                                                seed=1).tolist())
    s = state
    ckpt.save(s, d, step=0)
    i = 0
    while i < n_steps:
        if i in fails:
            fails.discard(i)     # fail once per scheduled step
            s, at = ckpt.restore(s, d)   # crash: reload latest
            i = at
            continue
        s, _ = step(s, ds.batch_at(i))
        i += 1
        if i % 2 == 0:
            ckpt.save(s, d, step=i)
    for a, b in zip(jax.tree.leaves(golden.params), jax.tree.leaves(s.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_mesh_plan():
    p = fault.elastic_mesh_plan(512, want_model=16, multi_pod=True)
    assert p.shape == (2, 16, 16) and p.dropped == 0
    p = fault.elastic_mesh_plan(511, want_model=16)
    assert p.shape == (31, 16) and p.dropped == 511 - 31 * 16
    p = fault.elastic_mesh_plan(8, want_model=16)
    assert p.shape[-1] <= 8
    per, accum = fault.rebalance_batch(256, old_data=16, new_data=15)
    assert per * 15 <= 256 and per >= 1


def test_straggler_monitor():
    mon = fault.StragglerMonitor(alpha=0.3, threshold=2.5)
    flags = [mon.observe(0.1) for _ in range(50)]
    assert not any(flags)
    assert mon.observe(10.0)     # 100x step time -> flagged


def test_guarded_step_retries():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise fault.TransientError("link flap")
        return state + 1, {}

    out, _ = fault.guarded_step(flaky, 1, None, retries=3)
    assert out == 2 and calls["n"] == 3


def test_data_determinism_and_resharding():
    ds, _ = make_loader(vocab=1000, seq_len=8, global_batch=8, n_shards=1)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch_at(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # resharding keeps per-shard streams independent and deterministic
    a = SyntheticLM(1000, 8, 4, n_shards=2, shard_id=0).batch_at(3)
    b = SyntheticLM(1000, 8, 4, n_shards=2, shard_id=1).batch_at(3)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))
