"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
all in interpret mode (CPU container; TPU is the target)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.ops import ttmc_fiber, ttmc_fiber_layout
from repro.kernels.util import padded_segment_layout
from repro.sparse import build_csf, random_sparse
from repro.sparse.csf import level_segments


@pytest.mark.parametrize("shape,density,R,block", [
    ((12, 10, 8), 0.1, 8, 8),
    ((30, 17, 9), 0.05, 16, 8),
    ((6, 6, 6), 0.5, 4, 16),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_mttkrp_sweep(shape, density, R, block, dtype, rng):
    T = random_sparse(shape, density, seed=7, dtype=dtype)
    csf = build_csf(T)
    B = jnp.asarray(rng.standard_normal((shape[1], R)).astype(dtype))
    C = jnp.asarray(rng.standard_normal((shape[2], R)).astype(dtype))
    out_ref = ops.mttkrp(csf, B, C, use_pallas=False)
    out = ops.mttkrp(csf, B, C, block=block, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-4)


@pytest.mark.parametrize("shape,R,S,block", [
    ((10, 9, 8), 8, 4, 8),
    ((24, 12, 6), 16, 16, 16),
])
def test_ttmc_fiber_sweep(shape, R, S, block, rng):
    T = random_sparse(shape, 0.1, seed=3)
    csf = build_csf(T)
    n2 = csf.nfib[2]
    Xf = jnp.asarray(rng.standard_normal((n2, S)).astype(np.float32))
    Ug = jnp.asarray(rng.standard_normal((n2, R)).astype(np.float32))
    lay = ttmc_fiber_layout(csf, block=block)
    o_pal = ttmc_fiber(Ug, Xf, lay, use_pallas=True)
    seg = jnp.asarray(level_segments(csf, 2, 1))
    o_ref = ref.ttmc_fiber_ref(Xf, Ug, seg, csf.nfib[1])
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=1e-4)


@pytest.mark.parametrize("shape,R,block", [
    ((12, 10, 8), 8, 16),
    ((5, 5, 5), 3, 8),
])
def test_tttp_sweep(shape, R, block, rng):
    T = random_sparse(shape, 0.2, seed=11)
    csf = build_csf(T)
    U = jnp.asarray(rng.standard_normal((shape[0], R)).astype(np.float32))
    V = jnp.asarray(rng.standard_normal((shape[1], R)).astype(np.float32))
    W = jnp.asarray(rng.standard_normal((shape[2], R)).astype(np.float32))
    o1 = ops.tttp(csf, U, V, W, use_pallas=False)
    o2 = ops.tttp(csf, U, V, W, block=block, use_pallas=True)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=1e-4)


@pytest.mark.parametrize("E,C,D,F,tiles", [
    (4, 16, 32, 24, dict(bc=8, bf=8, bd=16)),
    (2, 8, 8, 8, dict(bc=8, bf=8, bd=8)),
    (8, 32, 16, 64, dict(bc=16, bf=32, bd=16)),
])
@pytest.mark.parametrize("dtype", [np.float32, np.dtype("bfloat16")])
def test_grouped_matmul_sweep(E, C, D, F, tiles, dtype, rng):
    x = jnp.asarray(rng.standard_normal((E, C, D)), jnp.dtype(dtype))
    w = jnp.asarray(rng.standard_normal((E, D, F)), jnp.dtype(dtype))
    g1 = ops.grouped_matmul(x, w, use_pallas=False)
    g2 = ops.grouped_matmul(x, w, use_pallas=True, **tiles)
    atol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(g2, np.float32),
                               np.asarray(g1, np.float32), atol=atol)


@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 16, 2, 8, 8),
    (1, 32, 4, 16, 16),
    (3, 8, 1, 4, 8),
])
def test_wkv6_sweep(B, T, H, K, chunk, rng):
    r, k, v, w = (jnp.asarray(rng.standard_normal((B, T, H, K))
                              .astype(np.float32)) * 0.5 for _ in range(4))
    u = jnp.asarray(rng.standard_normal((H, K)).astype(np.float32)) * 0.5
    o1 = ops.wkv6(r, k, v, w, u, use_pallas=False)
    o2 = ops.wkv6(r, k, v, w, u, use_pallas=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=1e-3)


@pytest.mark.parametrize("B,T,D,chunk", [(2, 16, 8, 8), (1, 64, 32, 16)])
def test_rglru_sweep(B, T, D, chunk, rng):
    x = jnp.asarray(rng.standard_normal((B, T, D)).astype(np.float32))
    a = jnp.asarray(rng.uniform(0.05, 0.98, (B, T, D)).astype(np.float32))
    o1 = ops.rglru(x, a, use_pallas=False)
    o2 = ops.rglru(x, a, use_pallas=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=1e-4)


@pytest.mark.parametrize("T,H,D,window,bq", [
    (32, 2, 16, 12, 8),
    (64, 1, 32, 64, 16),   # window == T: degenerates to causal
    (16, 2, 8, 4, 8),
])
def test_local_attn_sweep(T, H, D, window, bq, rng):
    q, k, v = (jnp.asarray(rng.standard_normal((1, T, H, D))
                           .astype(np.float32)) for _ in range(3))
    o1 = ops.local_attn(q, k, v, window=window, use_pallas=False)
    o2 = ops.local_attn(q, k, v, window=window, use_pallas=True,
                        bq=bq, bk=bq)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=1e-3)


def test_padded_segment_layout_invariants(rng):
    # static checks incl. empty segments
    seg = np.array([0, 0, 2, 2, 2, 5])
    lay = padded_segment_layout(seg, nseg=6, block=4)
    assert lay.padded_len % 4 == 0
    assert lay.block_seg.shape[0] == lay.nblocks
    # every segment (even empty ones) owns at least one block
    assert set(lay.block_seg.tolist()) == set(range(6))
    # mask picks out exactly the real slots, in order
    real = np.flatnonzero(lay.mask)
    np.testing.assert_array_equal(lay.gather[real], np.arange(len(seg)))
    # first-block flags: exactly one per segment
    assert lay.block_first.sum() == 6
