"""Executor equivalence: Algorithm-2 reference interpreter and the
vectorized JAX engine must both match the dense einsum oracle, for every
enumerated fully-fused loop nest (property-based)."""
import itertools

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import numpy as np

from repro.core import spec as S
from repro.core.executor import (CSFArrays, VectorizedExecutor, dense_oracle,
                                 execute_unfactorized, reference_execute)
from repro.core.loopnest import enumerate_orders
from repro.core.paths import min_depth_paths
from repro.core.planner import plan
from repro.sparse import build_csf, random_sparse

from tests.test_order_dp import spttn_specs


def _factors(spec, rng):
    out = {}
    for t in spec.inputs:
        if not t.is_sparse:
            out[t.name] = rng.standard_normal(
                [spec.dims[i] for i in t.indices]).astype(np.float32)
    return out


def _sparse_out_to_dense(spec, csf, vals):
    dense = np.zeros([spec.dims[i] for i in spec.output.indices])
    dense[tuple(csf.coo.coords.T)] = np.asarray(vals)
    return dense


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(spec=spttn_specs(), seed=st.integers(0, 10_000))
def test_all_engines_match_oracle(spec, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    T = random_sparse(shape, density=0.4, seed=seed)
    hypothesis.assume(T.nnz > 0)
    csf = build_csf(T)
    factors = _factors(spec, rng)
    oracle = dense_oracle(spec, csf, factors)
    arrays = CSFArrays.from_csf(csf)

    for path in min_depth_paths(spec, max_paths=3, slack=1):
        for order in itertools.islice(
                enumerate_orders(path, spec.sparse_indices), 4):
            ref = reference_execute(spec, path, order, csf, factors)
            np.testing.assert_allclose(ref, oracle, atol=1e-4, err_msg=str(
                [str(t) for t in path]) + str(order))
            out = VectorizedExecutor(spec, path, order)(arrays, factors)
            if spec.output_is_sparse:
                out = _sparse_out_to_dense(spec, csf, out)
            np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-3)

    unf = execute_unfactorized(spec, arrays, factors)
    if spec.output_is_sparse:
        unf = _sparse_out_to_dense(spec, csf, unf)
    np.testing.assert_allclose(np.asarray(unf), oracle, atol=1e-3)


def test_planner_plans_execute_for_paper_kernels():
    rng = np.random.default_rng(1)
    cases = [
        S.mttkrp(6, 7, 8, 4),
        S.ttmc3(6, 7, 8, 4, 3),
        S.tttp3(6, 7, 8, 4),
        S.ttmc4(4, 5, 6, 7, 3, 2, 2),
        S.sddmm(6, 7, 4),
    ]
    for spec in cases:
        shape = tuple(spec.dims[i] for i in spec.sparse_indices)
        T = random_sparse(shape, density=0.3, seed=3)
        csf = build_csf(T)
        factors = _factors(spec, rng)
        oracle = dense_oracle(spec, csf, factors)
        pl = plan(spec, nnz_levels=csf.nnz_levels())
        out = VectorizedExecutor(spec, pl.path, pl.order)(
            CSFArrays.from_csf(csf), factors)
        if spec.output_is_sparse:
            out = _sparse_out_to_dense(spec, csf, out)
        np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-3,
                                   err_msg=str(spec))


def test_tttc_order6_plan_and_execute():
    spec = S.tttc6(4, 3)
    T = random_sparse(tuple(spec.dims[i] for i in spec.sparse_indices),
                      density=0.02, seed=5)
    csf = build_csf(T)
    rng = np.random.default_rng(2)
    factors = _factors(spec, rng)
    pl = plan(spec, nnz_levels=csf.nnz_levels(), max_paths=24)
    out = VectorizedExecutor(spec, pl.path, pl.order)(
        CSFArrays.from_csf(csf), factors)
    oracle = dense_oracle(spec, csf, factors)
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-3)


def test_empty_and_single_nnz():
    spec = S.mttkrp(4, 4, 4, 2)
    rng = np.random.default_rng(0)
    factors = _factors(spec, rng)
    from repro.sparse.coo import from_coords
    T1 = from_coords(np.array([[1, 2, 3]]), np.array([2.0], np.float32),
                     (4, 4, 4))
    csf = build_csf(T1)
    pl = plan(spec, nnz_levels=csf.nnz_levels())
    out = VectorizedExecutor(spec, pl.path, pl.order)(
        CSFArrays.from_csf(csf), factors)
    np.testing.assert_allclose(np.asarray(out),
                               dense_oracle(spec, csf, factors), atol=1e-4)
