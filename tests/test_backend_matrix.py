"""Cross-backend differential test matrix (docs/backends.md).

Every paper kernel x backend x reduction strategy runs against the
Algorithm-2 reference interpreter at 1e-5 — the correctness witness for
the target-neutral stage IR: both Pallas lowerings (TPU sequential-grid
accumulator, Mosaic-GPU split-K + segment-combine) consume the *same*
emitted IR, so a mismatch isolates to one target's lowering, never to
stage construction.  The degenerate layouts from ``test_codegen_edges``
(zero nnz, single segment, all-singleton segments) ride through the
same matrix.  All Pallas execution is interpret-mode (CPU container).

``SPTTN_TEST_BACKENDS`` (comma-separated) restricts the backend axis —
CI's gpu-interpret step sets it to ``pallas-gpu`` to prove the new
lowering in isolation.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis.invariants import fusible_chains
from repro.core import spec as S
from repro.core.executor import (CSFArrays, dense_oracle, execute_plan,
                                 make_executor, plan_from_json,
                                 plan_to_json, reference_execute)
from repro.core.planner import plan
from repro.sparse import build_csf, random_sparse
from repro.sparse.coo import from_coords
from tests.test_codegen_edges import (_single_segment_csf,
                                      _singleton_segment_csf)

BACKENDS_UNDER_TEST = tuple(
    b for b in os.environ.get("SPTTN_TEST_BACKENDS",
                              "xla,pallas,pallas-gpu").split(",") if b)

STRATEGIES = ("row", "segsum", "fused", "auto")

# the four paper kernels of §2.3/§7, at the sizes test_codegen.py uses
MATRIX_KERNELS = [
    pytest.param(S.mttkrp(6, 7, 8, 4), 0.3, id="mttkrp"),
    pytest.param(S.ttmc3(6, 7, 8, 4, 3), 0.3, id="ttmc"),
    pytest.param(S.tttp3(6, 7, 8, 4), 0.3, id="tttp"),
    pytest.param(S.tttc6(4, 3), 0.02, id="tttc"),
]


def _factors(spec, rng):
    return {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32)
        for t in spec.inputs if not t.is_sparse}


def _densify(spec, csf, out):
    if not spec.output_is_sparse:
        return np.asarray(out)
    dense = np.zeros([spec.dims[i] for i in spec.output.indices])
    dense[tuple(csf.coo.coords.T)] = np.asarray(out)
    return dense


def _engine_kwargs(backend, strategy):
    """The (backend, strategy) cell's engine kwargs, or None to skip."""
    if backend == "xla":
        # xla has no strategy axis — run it once, on the 'auto' row
        return {} if strategy == "auto" else None
    return {"strategy": strategy, "block": 8}


# --------------------------------------------------------------------- #
# the matrix: paper kernels x backends x strategies vs the reference
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("backend", BACKENDS_UNDER_TEST)
@pytest.mark.parametrize("spec,density", MATRIX_KERNELS)
def test_matrix_matches_reference(spec, density, backend, strategy):
    kwargs = _engine_kwargs(backend, strategy)
    if kwargs is None:
        pytest.skip("xla has no strategy axis")
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, density, seed=3))
    rng = np.random.default_rng(1)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    if strategy == "fused" and backend != "xla" \
            and not fusible_chains(spec, p.path):
        pytest.skip("no fusible chain on this kernel's planned path")
    ex = make_executor(spec, p.path, p.order, backend=backend,
                       interpret=True, **kwargs)
    out = _densify(spec, csf, ex(CSFArrays.from_csf(csf), factors))
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    np.testing.assert_allclose(out, ref, atol=1e-5,
                               err_msg=f"{backend}/{strategy}")
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-5)


# --------------------------------------------------------------------- #
# degenerate layouts from test_codegen_edges, through every cell
# --------------------------------------------------------------------- #
def _zero_nnz_csf():
    return build_csf(from_coords(np.zeros((0, 3), np.int64),
                                 np.zeros((0,), np.float32), (6, 7, 8)))


EDGE_LAYOUTS = [
    pytest.param(_zero_nnz_csf, id="zero-nnz"),
    pytest.param(_single_segment_csf, id="single-segment"),
    pytest.param(_singleton_segment_csf, id="all-singleton"),
]


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("backend", BACKENDS_UNDER_TEST)
@pytest.mark.parametrize("make_csf", EDGE_LAYOUTS)
def test_edge_layouts_across_backends(make_csf, backend, strategy):
    kwargs = _engine_kwargs(backend, strategy)
    if kwargs is None:
        pytest.skip("xla has no strategy axis")
    spec = S.mttkrp(6, 7, 8, 4)
    csf = make_csf()
    rng = np.random.default_rng(2)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = make_executor(spec, p.path, p.order, backend=backend,
                       interpret=True, **kwargs)
    out = np.asarray(ex(CSFArrays.from_csf(csf), factors))
    oracle = dense_oracle(spec, csf, factors)
    if csf.nnz == 0:
        assert out.shape == (6, 4)
        np.testing.assert_array_equal(out, np.zeros((6, 4), np.float32))
    np.testing.assert_allclose(out, oracle, atol=1e-5,
                               err_msg=f"{backend}/{strategy}")


# --------------------------------------------------------------------- #
# the IR invariant: both Pallas targets consume identical emitted IR
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["auto", "fused"])
def test_emitted_ir_identical_across_pallas_targets(strategy):
    """The stage IR is target-neutral by construction: the executor
    emits the same ``StageIR`` sequence whichever lowering consumes it,
    so a cross-target output mismatch can only live in a lowering."""
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(random_sparse((6, 7, 8), 0.3, seed=3))
    arrays = CSFArrays.from_csf(csf)
    rng = np.random.default_rng(1)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    e_tpu = make_executor(spec, p.path, p.order, backend="pallas",
                          block=8, interpret=True, strategy=strategy)
    e_gpu = make_executor(spec, p.path, p.order, backend="pallas-gpu",
                          block=8, interpret=True, strategy=strategy)
    out_t = np.asarray(e_tpu(arrays, factors))
    out_g = np.asarray(e_gpu(arrays, factors))
    assert e_tpu.emitted_ir, "executor recorded no stage IR"
    assert e_tpu.emitted_ir == e_gpu.emitted_ir
    if strategy == "fused":
        assert any(ir.kind == "chain" for ir in e_tpu.emitted_ir)
    np.testing.assert_allclose(out_t, out_g, atol=1e-6)


# --------------------------------------------------------------------- #
# acceptance: tuner persists and replays a pallas-gpu winner
# --------------------------------------------------------------------- #
def _mttkrp_inputs():
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    rng = np.random.default_rng(0)
    factors = {t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}
    return spec, csf, factors


def test_tuner_three_backend_axis_and_gpu_winner_round_trip(tmp_path):
    from repro.autotune import TunerConfig, tune
    spec, csf, factors = _mttkrp_inputs()
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})

    # all three backends reach the timer; the winner is one of them
    cfg = TunerConfig(max_paths=2, max_candidates=3, orders_per_path=1,
                      warmup=1, repeats=2,
                      backends=("xla", "pallas", "pallas-gpu"))
    tuned, stats = tune(spec, csf=csf, factors=factors, tuner=cfg)
    assert tuned.backend in ("xla", "pallas", "pallas-gpu")
    assert stats.candidates_timed >= 3

    # forced pallas-gpu winner: persists to the cache, replays as a hit,
    # and survives the plan JSON round trip onto its tuned backend
    forced = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                         warmup=1, repeats=2, backends=("pallas-gpu",))
    p1 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=forced)
    assert p1.backend == "pallas-gpu" and not p1.stats.cache_hit
    p2 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=forced)
    assert p2.stats.cache_hit and p2.backend == "pallas-gpu"
    assert p1 == p2
    rt = plan_from_json(plan_to_json(p2))
    assert rt == p2 and rt.backend == "pallas-gpu"
    out = execute_plan(rt, CSFArrays.from_csf(csf), factors, block=8)
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-4)


def test_gpu_backend_distinct_cache_key():
    """A pallas-gpu search must never be served a pallas (TPU) cache
    entry — the backend axis is part of the cache key."""
    from repro.autotune import cache_key
    spec, csf, _ = _mttkrp_inputs()
    levels = csf.nnz_levels()
    keys = {cache_key(spec, levels, "cpu:x", backends=bs)
            for bs in (("pallas",), ("pallas-gpu",),
                       ("xla", "pallas", "pallas-gpu"))}
    assert len(keys) == 3
