"""Direct unit tests for repro.distributed.collectives.

The multi-device integration angles (8-shard unbiasedness, ZeRO-2 shapes
under a real FSDP axis) live in tests/test_distributed.py; this module
pins the primitives themselves: (a) the stochastic-rounding quantizer is
unbiased with bounded variance — tested without any mesh, the math is
device-free; (b) ``compressed_psum`` on a 1-shard mesh reduces to an
(unbiased) quantize/dequantize round trip and is exact on zeros; (c)
``reduce_scatter_grads`` falls back to a whole-tensor psum for leaves
whose leading dim does not divide the axis (subprocess, 4 devices); (d)
the ``shard_map`` shim routes through both jax APIs — the new
``jax.shard_map(check_vma=)`` spelling (faked when absent) and the
``jax.experimental.shard_map(check_rep=)`` one.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import collectives
from repro.distributed.collectives import (_dequantize_block,
                                           _quantize_block,
                                           compressed_psum,
                                           reduce_scatter_grads, shard_map)
from tests.conftest import run_with_devices


# --------------------------------------------------------------------- #
# (a) the quantizer: unbiased, variance-bounded, pure function
# --------------------------------------------------------------------- #
def test_quantizer_unbiased_and_variance_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, 100)).astype(np.float32)) * 5.0
    n = 200
    outs = []
    for s in range(n):
        q, scale, shape, pad = _quantize_block(x, jax.random.PRNGKey(s))
        outs.append(np.asarray(_dequantize_block(q, scale, shape, pad)))
    outs = np.stack(outs)
    scale_np = np.asarray(scale).max()
    # unbiased: the empirical mean converges to x (CLT tolerance ~4 sigma
    # of the mean estimator; per-sample sd <= scale/2, the worst case of
    # uniform stochastic rounding)
    tol = 4.0 * (scale_np / 2.0) / np.sqrt(n)
    assert np.abs(outs.mean(0) - np.asarray(x)).max() < tol + 1e-6
    # variance of uniform stochastic rounding is at most scale^2 / 4
    assert outs.var(0).max() <= scale_np**2 / 4 + 1e-6


def test_quantizer_pads_and_restores_shape():
    x = jnp.arange(10, dtype=jnp.float32).reshape(2, 5)  # 10 % 256 != 0
    q, scale, shape, pad = _quantize_block(x, jax.random.PRNGKey(0))
    assert pad == 256 - 10 and shape == (2, 5)
    back = _dequantize_block(q, scale, shape, pad)
    assert back.shape == (2, 5)
    # max-abs scaling keeps every value within one quantum of the input
    assert np.abs(np.asarray(back) - np.asarray(x)).max() \
        <= float(np.asarray(scale).max()) + 1e-6


# --------------------------------------------------------------------- #
# (b) compressed_psum on a single-shard mesh (in-process, 1 device)
# --------------------------------------------------------------------- #
def test_compressed_psum_single_shard_round_trip():
    mesh = jax.make_mesh((1,), ("d",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 128)).astype(np.float32)) * 2.0

    def f(xs, key):
        return compressed_psum(xs, "d", key)

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("d"), P()),
                          out_specs=P("d"), check_vma=False))
    out = np.asarray(g(x, jax.random.PRNGKey(0)))
    # one shard: the psum is a quantize/dequantize round trip — within
    # one quantization step of the input everywhere
    step = np.abs(np.asarray(x)).max() / 127.0
    assert np.abs(out - np.asarray(x)).max() <= step + 1e-6


def test_compressed_psum_exact_on_zeros():
    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.zeros((1, 64), jnp.float32)
    g = jax.jit(shard_map(lambda xs, k: compressed_psum(xs, "d", k),
                          mesh=mesh, in_specs=(P("d"), P()),
                          out_specs=P("d"), check_vma=False))
    np.testing.assert_array_equal(np.asarray(g(x, jax.random.PRNGKey(0))),
                                  np.zeros((1, 64), np.float32))


# --------------------------------------------------------------------- #
# (c) reduce_scatter_grads: divisible leaves scatter, the rest psum whole
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_reduce_scatter_non_divisible_fallback():
    code = """
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import reduce_scatter_grads, shard_map

mesh = jax.make_mesh((4,), ("d",))
rng = np.random.default_rng(0)
grads = {
    "w": jnp.asarray(rng.standard_normal((4, 8, 3)).astype(np.float32)),
    "odd": jnp.asarray(rng.standard_normal((4, 5)).astype(np.float32)),
    "scalar": jnp.asarray(rng.standard_normal(4).astype(np.float32)),
}

def f(g):
    g = {"w": g["w"].reshape(8, 3), "odd": g["odd"].reshape(5),
         "scalar": g["scalar"].reshape(())}
    out = reduce_scatter_grads(g, "d")
    # divisible leaf: each shard holds only its slice (ZeRO-2 shape)
    assert out["w"].shape == (2, 3), out["w"].shape
    # non-divisible and scalar leaves: whole-tensor psum fallback
    assert out["odd"].shape == (5,), out["odd"].shape
    assert out["scalar"].shape == (), out["scalar"].shape
    return out["w"], out["odd"], out["scalar"]

g = jax.jit(shard_map(f, mesh=mesh,
    in_specs=({"w": P("d"), "odd": P("d"), "scalar": P("d")},),
    out_specs=(P("d"), P(), P()), check_vma=False))
w, odd, scalar = g(grads)
np.testing.assert_allclose(np.asarray(w),
                           np.asarray(grads["w"]).sum(0), atol=1e-5)
np.testing.assert_allclose(np.asarray(odd),
                           np.asarray(grads["odd"]).sum(0), atol=1e-5)
np.testing.assert_allclose(np.asarray(scalar),
                           np.asarray(grads["scalar"]).sum(), atol=1e-5)
print("RS-FALLBACK-OK")
"""
    out = run_with_devices(code, 4)
    assert "RS-FALLBACK-OK" in out


# --------------------------------------------------------------------- #
# (d) the shard_map shim: both jax API spellings
# --------------------------------------------------------------------- #
def test_shard_map_shim_new_api_branch(monkeypatch):
    """When ``jax.shard_map`` exists the shim must call it with
    ``check_vma`` (the new spelling), passing everything through."""
    seen = {}

    def fake(f, mesh, in_specs, out_specs, check_vma):
        seen.update(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_vma)
        return "sentinel"

    monkeypatch.setattr(jax, "shard_map", fake, raising=False)
    out = shard_map(lambda x: x, mesh="m", in_specs=(P(),),
                    out_specs=P(), check_vma=False)
    assert out == "sentinel"
    assert seen == {"mesh": "m", "in_specs": (P(),), "out_specs": P(),
                    "check_vma": False}


def test_shard_map_shim_experimental_branch(monkeypatch):
    """Without ``jax.shard_map`` the shim must reach the experimental
    API and translate ``check_vma`` to ``check_rep``."""
    if hasattr(jax, "shard_map"):
        monkeypatch.delattr(jax, "shard_map")
    mesh = jax.make_mesh((1,), ("d",))
    fn = jax.jit(collectives.shard_map(
        lambda x: jax.lax.psum(x, "d"), mesh=mesh,
        in_specs=(P("d"),), out_specs=P(), check_vma=False))
    out = np.asarray(fn(jnp.ones((1, 4), jnp.float32)))
    np.testing.assert_array_equal(out, np.ones((1, 4), np.float32))
