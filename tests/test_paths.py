"""Contraction-path enumeration (paper §4.1.1, Def 4.1)."""
import pytest

from repro.core import spec as S
from repro.core import paths as P


def test_count_formula():
    # T(n) = C(n,2) T(n-1), T(2)=1  ->  1, 3, 18, 180
    assert [P.count_paths(n) for n in (2, 3, 4, 5)] == [1, 3, 18, 180]


@pytest.mark.parametrize("builder,n", [
    (lambda: S.mttkrp(4, 5, 6, 3), 3),
    (lambda: S.ttmc3(4, 5, 6, 3, 2), 3),
    (lambda: S.tttp3(4, 5, 6, 3), 4),
])
def test_enumeration_matches_formula(builder, n):
    sp = builder()
    paths = list(P.enumerate_paths(sp))
    assert len(paths) == P.count_paths(n)
    # every path has N-1 terms for N inputs and ends at OUT
    for p in paths:
        assert len(p) == n - 1
        assert p[-1].out.name == "OUT"
        assert set(p[-1].out.indices) == set(sp.output.indices)


def test_consumer_map_is_binary_tree():
    sp = S.tttp3(4, 5, 6, 3)
    for path in P.enumerate_paths(sp):
        cons = P.consumer_map(path)
        # every non-final term has exactly one consumer, later in the path
        assert set(cons) == set(range(len(path) - 1))
        assert all(v > k for k, v in cons.items())


def test_min_depth_filter():
    sp = S.ttmc3(4, 5, 6, 3, 2)
    md = P.min_depth_paths(sp)
    depths = [P.path_depth(p) for p in md]
    assert all(d == depths[0] for d in depths)
    # TTMc min depth = 4 (paper §2.4.2), unfused depth would be 5
    assert depths[0] == 4
    # Fig 1d path (U.V first) has depth 5 and is filtered out
    all_depths = sorted({P.path_depth(p) for p in P.enumerate_paths(sp)})
    assert all_depths == [4, 5]


def test_intermediate_sparse_prefix_ordering():
    sp = S.mttkrp(4, 5, 6, 3)
    for path in P.enumerate_paths(sp):
        for t in path:
            sp_inds = [i for i in t.out.indices if i in ("i", "j", "k")]
            # sparse indices stay in storage order in intermediates
            assert sp_inds == sorted(sp_inds, key="ijk".index)
