import os
import subprocess
import sys

import numpy as np
import pytest

# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr}")
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
