"""MoE dispatch: the SpTTN planner's factorize-and-fuse (grouped) schedule
must equal the unfactorized one-hot einsum; the planner must pick grouped
for every realistic size (the paper's asymptotic argument)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.moe import (_capacity, choose_dispatch, moe_apply,
                              moe_init)


@pytest.fixture
def setup():
    cfg = get_reduced("granite-moe-1b-a400m")
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_grouped_equals_onehot(setup):
    cfg, p, x = setup
    y1, a1 = moe_apply(p, cfg, x, deterministic_dispatch="onehot")
    y2, a2 = moe_apply(p, cfg, x, deterministic_dispatch="grouped")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-6)


def test_planner_chooses_grouped():
    # every real configuration: nnz (N*k) << dense (N*E*C)
    for n_tok, E, k in [(4096, 32, 8), (1 << 20, 160, 6), (512, 8, 2)]:
        from repro.configs.base import MoEConfig
        C = _capacity(MoEConfig(n_experts=E, top_k=k, d_expert=64), n_tok)
        assert choose_dispatch(n_tok, E, k, C, 1024) == "grouped"


def test_capacity_drops_are_weighted_zero(setup):
    """Over-capacity tokens contribute nothing (not garbage)."""
    cfg, p, x = setup
    import dataclasses
    tight = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.25))
    y, _ = moe_apply(p, tight, x, deterministic_dispatch="grouped")
    y2, _ = moe_apply(p, tight, x, deterministic_dispatch="onehot")
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)


def test_moe_grad_flows(setup):
    cfg, p, x = setup

    def loss(p):
        y, aux = moe_apply(p, cfg, x, deterministic_dispatch="grouped")
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router must receive gradient through the gate weights
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
