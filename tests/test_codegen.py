"""Pallas codegen backend: generated kernels for arbitrary SpTTN plans
must match the Algorithm-2 reference interpreter (and the dense oracle)
on every paper kernel, under both reduction-lowering strategies, and the
backend must round-trip through plan JSON v4, the autotuner, and the
disk plan cache.  All Pallas execution is interpret-mode (CPU container;
TPU is the compile target)."""
import itertools
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.autotune import TunerConfig, generate_candidates, tune
from repro.core import spec as S
from repro.core.executor import (BACKENDS, PLAN_JSON_VERSION, CSFArrays,
                                 dense_oracle, execute_plan, make_executor,
                                 plan_from_dict, plan_from_json,
                                 plan_to_dict, plan_to_json,
                                 reference_execute)
from repro.core.loopnest import enumerate_orders
from repro.core.paths import min_depth_paths
from repro.core.planner import plan
from repro.kernels import ops
from repro.kernels.codegen import PallasPlanExecutor
from repro.sparse import build_csf, random_sparse


def _factors(spec, rng):
    return {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32)
        for t in spec.inputs if not t.is_sparse}


def _densify(spec, csf, out):
    if not spec.output_is_sparse:
        return np.asarray(out)
    dense = np.zeros([spec.dims[i] for i in spec.output.indices])
    dense[tuple(csf.coo.coords.T)] = np.asarray(out)
    return dense


# the four paper kernels of §2.3/§7 (+ the order-4/order-2 variants)
PAPER_KERNELS = [
    pytest.param(S.mttkrp(6, 7, 8, 4), 0.3, id="mttkrp"),
    pytest.param(S.ttmc3(6, 7, 8, 4, 3), 0.3, id="ttmc"),
    pytest.param(S.tttp3(6, 7, 8, 4), 0.3, id="tttp"),
    pytest.param(S.tttc6(4, 3), 0.02, id="tttc"),
    pytest.param(S.ttmc4(4, 5, 6, 7, 3, 2, 2), 0.2, id="ttmc4"),
    pytest.param(S.sddmm(6, 7, 4), 0.3, id="sddmm"),
]


@pytest.mark.parametrize("spec,density", PAPER_KERNELS)
def test_pallas_matches_reference_on_paper_kernels(spec, density):
    """Acceptance bar: generated Pallas (interpret) == reference_execute
    to 1e-5 on the planner's chosen schedule for every paper kernel."""
    rng = np.random.default_rng(1)
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, density, seed=3))
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    ex = make_executor(spec, p.path, p.order, backend="pallas",
                       block=16, interpret=True)
    out = _densify(spec, csf, ex(arrays, factors))
    np.testing.assert_allclose(out, ref, atol=1e-5, err_msg=str(spec))
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-5)


@pytest.mark.parametrize("strategy", ["row", "segsum"])
def test_reduction_strategies_agree(strategy):
    """Both reduction lowerings (fused VMEM row accumulation vs fused
    product + XLA segmented sum) compute the same answer."""
    spec = S.mttkrp(10, 8, 6, 4)
    csf = build_csf(random_sparse((10, 8, 6), 0.25, seed=7))
    rng = np.random.default_rng(2)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy=strategy)
    np.testing.assert_allclose(np.asarray(ex(arrays, factors)), ref,
                               atol=1e-5)


def test_pallas_sweep_over_enumerated_loop_nests():
    """The generator handles arbitrary (path, order) schedules, not just
    the planner's pick — a few per paper kernel against the reference."""
    rng = np.random.default_rng(3)
    for spec, density in [(S.mttkrp(6, 7, 8, 4), 0.3),
                          (S.ttmc3(6, 7, 8, 4, 3), 0.3)]:
        shape = tuple(spec.dims[i] for i in spec.sparse_indices)
        csf = build_csf(random_sparse(shape, density, seed=5))
        factors = _factors(spec, rng)
        arrays = CSFArrays.from_csf(csf)
        for path in min_depth_paths(spec, max_paths=3, slack=1):
            for order in itertools.islice(
                    enumerate_orders(path, spec.sparse_indices), 3):
                ex = PallasPlanExecutor(spec, path, order, block=8,
                                        interpret=True)
                ref = reference_execute(spec, path, order, csf, factors)
                np.testing.assert_allclose(
                    np.asarray(ex(arrays, factors)), ref, atol=1e-5,
                    err_msg=str([str(t) for t in path]) + str(order))


def test_pallas_jit_and_single_nnz():
    spec = S.mttkrp(6, 7, 8, 4)
    rng = np.random.default_rng(4)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    from repro.sparse.coo import from_coords
    csf = build_csf(from_coords(np.array([[1, 2, 3]]),
                                np.array([2.0], np.float32), (6, 7, 8)))
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8, interpret=True)
    fn = jax.jit(lambda f: ex(arrays, f))
    out = np.asarray(fn(factors))
    np.testing.assert_allclose(
        out, dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()}),
        atol=1e-5)
    np.testing.assert_allclose(out, np.asarray(fn(factors)))  # cached call


def test_handwritten_mttkrp_is_a_regression_fixture():
    """The retired special case: ops.mttkrp (hand-fused leaf kernel) must
    agree with reference_execute and with the generated kernel."""
    spec = S.mttkrp(12, 10, 8, 8)
    csf = build_csf(random_sparse((12, 10, 8), 0.1, seed=7))
    rng = np.random.default_rng(5)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    # the leaf kernel emits one row per nonempty level-1 fiber; scatter
    # rows to their i coordinates for the dense comparison
    rows = np.asarray(ops.mttkrp(csf, jnp.asarray(factors["B"]),
                                 jnp.asarray(factors["C"]), block=8,
                                 use_pallas=True))
    hand = np.zeros_like(ref)
    hand[csf.coord[1]] = rows
    np.testing.assert_allclose(hand, ref, atol=1e-4)
    gen = np.asarray(PallasPlanExecutor(spec, p.path, p.order, block=8,
                                        interpret=True)(
        CSFArrays.from_csf(csf), factors))
    np.testing.assert_allclose(gen, ref, atol=1e-5)


# --------------------------------------------------------------------- #
# backend registry + plan JSON v4
# --------------------------------------------------------------------- #
def test_make_executor_backends_share_semantics():
    spec = S.ttmc3(6, 7, 8, 4, 3)
    csf = build_csf(random_sparse((6, 7, 8), 0.3, seed=9))
    rng = np.random.default_rng(6)
    factors = _factors(spec, rng)
    arrays = CSFArrays.from_csf(csf)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    outs = {b: _densify(spec, csf,
                        make_executor(spec, p.path, p.order, backend=b,
                                      **({"block": 8} if b == "pallas"
                                         else {}))(arrays, factors))
            for b in BACKENDS}
    for b, out in outs.items():
        np.testing.assert_allclose(out, outs["reference"], atol=1e-5,
                                   err_msg=b)
    with pytest.raises(ValueError, match="unknown backend"):
        make_executor(spec, p.path, p.order, backend="triton")


def test_plan_json_v5_round_trip_with_backend():
    spec = S.mttkrp(8, 6, 5, 3)
    p = plan(spec)
    import dataclasses
    tagged = dataclasses.replace(p, backend="pallas", fused=True, block=16)
    doc = plan_to_dict(tagged)
    assert doc["version"] == PLAN_JSON_VERSION == 6
    assert doc["backend"] == "pallas"
    assert doc["mesh"] is None            # single-device plan
    assert doc["fused"] is True
    assert doc["block"] == 16
    rt = plan_from_json(plan_to_json(tagged))
    assert rt == tagged and rt.backend == "pallas" and rt.fused
    assert rt.block == 16
    # a plan serialized without an explicit backend defaults to xla,
    # one without an explicit fused flag defaults to staged, and one
    # without an explicit block defaults to the engine default
    doc2 = plan_to_dict(p)
    del doc2["backend"]
    del doc2["fused"]
    del doc2["block"]
    rt2 = plan_from_dict(doc2)
    assert rt2.backend == "xla" and rt2.fused is False and rt2.block is None
    # a non-boolean fused flag is rejected, not coerced
    with pytest.raises(ValueError, match="plan fused"):
        plan_from_dict(dict(plan_to_dict(p), fused="yes"))
    # so is a non-integer, non-positive, or sublane-misaligned block —
    # compiled-mode replay would otherwise silently round it (rejected,
    # never coerced)
    for bad in ("128", 0, -8, True, 12):
        with pytest.raises(ValueError, match="plan block"):
            plan_from_dict(dict(plan_to_dict(p), block=bad))


@pytest.mark.parametrize("version", [1, 2, 3, 4, 5, None, "6"])
def test_plan_json_rejects_foreign_versions(version):
    """Forward/backward compat is re-plan-never-guess: any version other
    than the current one is rejected outright."""
    spec = S.mttkrp(8, 6, 5, 3)
    doc = plan_to_dict(plan(spec))
    doc["version"] = version
    with pytest.raises(ValueError, match="unsupported plan version"):
        plan_from_dict(doc)


def test_plan_json_rejects_unknown_backend():
    doc = plan_to_dict(plan(S.mttkrp(8, 6, 5, 3)))
    doc["backend"] = "cuda"
    with pytest.raises(ValueError, match="unknown plan backend"):
        plan_from_dict(doc)


# --------------------------------------------------------------------- #
# backend as an autotuning axis
# --------------------------------------------------------------------- #
FAST = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                   warmup=1, repeats=2, backends=("xla", "pallas"))


def _mttkrp_inputs():
    spec = S.mttkrp(16, 12, 10, 4)
    csf = build_csf(random_sparse((16, 12, 10), 0.1, seed=3))
    rng = np.random.default_rng(0)
    factors = {t.name: jnp.asarray(rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32))
        for t in spec.inputs if not t.is_sparse}
    return spec, csf, factors


def test_cache_key_includes_backend_axis(tmp_path):
    """A plan tuned under a forced backend axis must not be served as a
    cache hit to a search over a different axis."""
    from repro.autotune import cache_key
    spec, csf, factors = _mttkrp_inputs()
    levels = csf.nnz_levels()
    assert (cache_key(spec, levels, "cpu:x", backends=("pallas",)) !=
            cache_key(spec, levels, "cpu:x", backends=("xla",)))
    forced = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                         warmup=1, repeats=2, backends=("pallas",))
    xla_only = TunerConfig(max_paths=2, max_candidates=1,
                           orders_per_path=1, warmup=1, repeats=2,
                           backends=("xla",))
    p1 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=forced)
    p2 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=xla_only)
    assert p1.backend == "pallas"
    assert not p2.stats.cache_hit and p2.backend == "xla"


def test_all_dense_network_folds_pallas_into_xla():
    """backends=("pallas",) on an all-dense spec must not empty the
    candidate set — the generator has no sparse stages there, so the
    candidate degrades to the identical XLA engine."""
    spec = S.parse("ij,jk->ik", dims={"i": 6, "j": 5, "k": 4}, sparse=None)
    cands = generate_candidates(spec, max_paths=2, max_candidates=2,
                                orders_per_path=1, backends=("pallas",))
    assert cands and all(c.backend == "xla" for c in cands)
    both = generate_candidates(spec, max_paths=2, max_candidates=2,
                               orders_per_path=1,
                               backends=("xla", "pallas"))
    assert both and all(c.backend == "xla" for c in both)
    assert len({c.key for c in both}) == len(both)   # no double-measure


def test_candidates_expand_across_backends():
    spec, csf, _ = _mttkrp_inputs()
    cands = generate_candidates(spec, nnz_levels=csf.nnz_levels(),
                                max_paths=2, max_candidates=3,
                                orders_per_path=1,
                                backends=("xla", "pallas"))
    assert {c.backend for c in cands} == {"xla", "pallas"}
    assert len({c.key for c in cands}) == len(cands)
    assert cands[0].backend == "xla"      # model pick is on backends[0]
    with pytest.raises(ValueError, match="unknown backends"):
        generate_candidates(spec, backends=("cuda",))


def test_autotune_can_return_pallas_backend_plan(tmp_path):
    spec, csf, factors = _mttkrp_inputs()
    tuned, stats = tune(spec, csf=csf, factors=factors, tuner=FAST)
    assert tuned.backend in ("xla", "pallas")
    assert stats.candidates_timed >= 2    # both backends reached the timer

    forced = TunerConfig(max_paths=2, max_candidates=2, orders_per_path=1,
                         warmup=1, repeats=2, backends=("pallas",))
    p1 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=forced)
    assert p1.backend == "pallas" and not p1.stats.cache_hit
    # the winner (and its backend) is what lands in the plan cache
    p2 = plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
              factors=factors, tuner=forced)
    assert p2.stats.cache_hit and p2.backend == "pallas"
    assert p1 == p2
    # and the persisted plan executes on its tuned backend
    out = execute_plan(p2, CSFArrays.from_csf(csf), factors, block=8)
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-4)


def test_cached_plan_meta_records_backends(tmp_path):
    spec, csf, factors = _mttkrp_inputs()
    import os
    plan(spec, autotune=True, cache_dir=str(tmp_path), csf=csf,
         factors=factors, tuner=FAST)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 1
    with open(tmp_path / files[0]) as f:
        doc = json.load(f)
    assert doc["plan"]["version"] == 6
    assert doc["cache_version"] == 7
    assert set(doc["meta"]["backends"]) == {"xla", "pallas"}
    assert all("backend" in t and "fused" in t and "block" in t
               for t in doc["meta"]["timings"])
