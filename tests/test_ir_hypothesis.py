"""Hypothesis properties of the target-neutral stage IR
(docs/backends.md).

(1) **IR neutrality + shape preservation** — for random operands and
enumerated loop nests, executors on both registered Pallas targets emit
the *identical* ``StageIR`` sequence and produce outputs of the logical
shape the reference interpreter produces (and the same values, to
float32 tolerance).  The IR is the contract: a lowering may reorder
partial sums but never reshape the logical result.

(2) **split-K combine exactness** — ``segment_combine`` (the Mosaic-GPU
reduce tail) equals a sequential left-to-right accumulation loop
bit-for-bit on float64: ``segment_sum`` over a sorted block->segment map
adds partials in ascending block order, the exact order the TPU
sequential-grid accumulator uses, so the two lowerings are not just
close — they are the same sum.

Skipped wholesale where hypothesis is not installed (the CI full lane
has it; minimal local envs may not).
"""
import numpy as np
import pytest

from repro.core import spec as S
from repro.core.executor import CSFArrays, make_executor, reference_execute
from repro.core.planner import plan
from repro.kernels.codegen import segment_combine
from repro.sparse import build_csf, random_sparse

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

KERNELS = {
    "mttkrp": lambda: S.mttkrp(6, 7, 8, 4),
    "ttmc": lambda: S.ttmc3(6, 7, 8, 4, 3),
    "tttc": lambda: S.tttc6(4, 3),
}


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       kernel=st.sampled_from(sorted(KERNELS)),
       density=st.floats(0.05, 0.4),
       strategy=st.sampled_from(["auto", "fused"]))
def test_lowerings_preserve_ir_and_logical_shapes(seed, kernel, density,
                                                  strategy):
    spec = KERNELS[kernel]()
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, density, seed=seed))
    if csf.nnz == 0:
        return
    rng = np.random.default_rng(seed)
    factors = {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32)
        for t in spec.inputs if not t.is_sparse}
    p = plan(spec, nnz_levels=csf.nnz_levels())
    arrays = CSFArrays.from_csf(csf)
    ref = np.asarray(reference_execute(spec, p.path, p.order, csf, factors))
    outs, irs = {}, {}
    for backend in ("pallas", "pallas-gpu"):
        ex = make_executor(spec, p.path, p.order, backend=backend,
                           block=8, interpret=True, strategy=strategy)
        outs[backend] = np.asarray(ex(arrays, factors))
        irs[backend] = list(ex.emitted_ir)
    assert irs["pallas"], "no stage IR emitted"
    assert irs["pallas"] == irs["pallas-gpu"]
    for backend, out in outs.items():
        if spec.output_is_sparse:
            assert out.shape[0] == csf.nnz, backend
        else:
            assert out.shape == ref.shape, backend
        np.testing.assert_allclose(out, ref, atol=1e-4, err_msg=backend)
    np.testing.assert_allclose(outs["pallas"], outs["pallas-gpu"],
                               atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000),
       nseg=st.integers(1, 9),
       width=st.sampled_from([1, 3, 8]),
       empty_head=st.booleans())
def test_segment_combine_is_bitexact_sequential_accumulation(
        seed, nseg, width, empty_head):
    rng = np.random.default_rng(seed)
    # sorted block->segment map with possibly empty segments (an empty
    # head exercises segments owning zero partials: exact zeros out)
    counts = rng.integers(0, 4, size=nseg)
    if empty_head:
        counts[0] = 0
    seg = np.repeat(np.arange(nseg), counts).astype(np.int32)
    parts = rng.standard_normal((len(seg), width)).astype(np.float64)
    # magnitude spread makes float addition order-observable, so the
    # bit-for-bit assertion below really pins the order
    parts *= 10.0 ** rng.integers(-6, 7, size=(len(seg), 1))
    from jax.experimental import enable_x64
    with enable_x64():
        got = np.asarray(segment_combine(parts, seg, nseg))
    want = np.zeros((nseg, width), np.float64)
    for b in range(len(seg)):             # ascending block order
        want[seg[b]] = want[seg[b]] + parts[b]
    assert got.dtype == np.float64
    np.testing.assert_array_equal(got, want)
