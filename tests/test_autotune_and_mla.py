"""Autotuning (paper §4: 'enumeration enables autotuning') and the
absorbed-MLA decode equivalence (§Perf bonus cell)."""
import itertools

import numpy as np

import jax
import jax.numpy as jnp


def test_autotune_selects_a_valid_fast_nest():
    from repro.core import spec as S
    from repro.core.executor import CSFArrays, dense_oracle
    from repro.core.loopnest import enumerate_orders
    from repro.core.paths import min_depth_paths
    from repro.core.planner import autotune
    from repro.sparse import build_csf, random_sparse

    spec = S.ttmc3(32, 24, 16, 8, 8)
    T = random_sparse((32, 24, 16), 0.05, seed=7)
    csf = build_csf(T)
    rng = np.random.default_rng(0)
    factors = {"U": jnp.asarray(rng.standard_normal((24, 8)).astype(np.float32)),
               "V": jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))}
    cands = []
    for path in min_depth_paths(spec, max_paths=2):
        for order in itertools.islice(
                enumerate_orders(path, spec.sparse_indices), 3):
            cands.append((path, order))
    (best_path, best_order), results = autotune(
        spec, csf, factors, cands, repeats=2)
    assert (best_path, best_order) in cands
    # measured times sorted ascending; the winner is the head
    assert results[0][1] == best_path and results[0][2] == best_order
    # and the winner computes the right answer
    from repro.core.executor import VectorizedExecutor
    out = VectorizedExecutor(spec, best_path, best_order)(
        CSFArrays.from_csf(csf), factors)
    oracle = dense_oracle(spec, csf,
                          {k: np.asarray(v) for k, v in factors.items()})
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-3)


def test_absorbed_mla_equals_naive_decode():
    """mla_apply_absorbed must match the naive (decompress-everything)
    MLA decode bit-for-bit up to float tolerance."""
    import dataclasses

    from repro.configs import get_reduced
    from repro.models import attention as A

    cfg = get_reduced("deepseek-v2-236b")
    p, _ = A.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 12
    rng = np.random.default_rng(0)
    m = cfg.mla
    cache = A.KVCache(
        k=jnp.asarray(rng.standard_normal(
            (B, S, m.kv_lora + m.qk_rope_dim)).astype(np.float32)) * 0.3,
        v=jnp.zeros((B, S, 0), jnp.float32))
    x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model))
                    .astype(np.float32)) * 0.3
    pos = jnp.asarray(7, jnp.int32)
    positions = jnp.full((B, 1), 7, jnp.int32)

    y_abs, c_abs = A.mla_apply_absorbed(p, cfg, x, positions, cache, pos)

    cfg_naive = dataclasses.replace(cfg, mla_absorb=False)
    y_naive, c_naive = A.mla_apply(p, cfg_naive, x, positions,
                                   cache=cache, update_slice=pos)
    np.testing.assert_allclose(np.asarray(y_abs), np.asarray(y_naive),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(c_abs.k), np.asarray(c_naive.k),
                               atol=1e-5)


def test_mla_absorb_flag_routes():
    from repro.configs import get_reduced
    from repro.models import attention as A
    cfg = get_reduced("deepseek-v2-236b")
    assert cfg.mla_absorb  # default on; mla_apply dispatches to absorbed
