"""The top-level ``repro`` facade and the reconciled kwarg surface.

Covers: (a) ``import repro`` is cheap — no submodule (and so no JAX)
import happens until an attribute is touched; (b) ``__all__`` is
complete and honest — every listed name resolves through the facade to
the same object its defining module exports; (c) ``tuner=`` is the
blessed TunerConfig kwarg — ``config=`` warns ``DeprecationWarning``
through one shared resolver and passing both is an error; (d) unknown
engine kwargs are rejected with the valid set named, and pallas-only
kwargs are rejected on non-pallas backends; (e) plan JSON v6
round-trips the slice stamp through the facade spellings and v5
documents are rejected.
"""
import importlib
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.core import spec as S


# --------------------------------------------------------------------- #
# (a) lazy facade: import repro touches nothing heavy
# --------------------------------------------------------------------- #
def test_import_repro_is_cheap():
    code = (
        "import sys, repro\n"
        "heavy = [m for m in sys.modules\n"
        "         if m == 'jax' or m.startswith(('jax.', 'repro.'))]\n"
        "assert not heavy, heavy\n"
        "assert repro.__version__\n"
        # first attribute access imports exactly the defining module
        "repro.mttkrp\n"
        "assert 'repro.core.spec' in sys.modules\n"
    )
    subprocess.run([sys.executable, "-c", code], check=True)


# --------------------------------------------------------------------- #
# (b) __all__ completeness: every export resolves and matches its module
# --------------------------------------------------------------------- #
def test_all_is_complete_and_resolves():
    assert "__version__" in repro.__all__
    assert sorted(repro.__all__) == sorted(set(repro.__all__))
    for name in repro.__all__:
        if name == "__version__":
            continue
        obj = getattr(repro, name)
        mod = importlib.import_module(repro._EXPORTS[name])
        assert obj is getattr(mod, name), name
        assert name in dir(repro)
    # the blessed workflow surface is present by name
    for required in ("plan", "tune", "execute_plan", "make_executor",
                     "build_csf", "random_sparse", "plan_peak_bytes",
                     "sliced_execute", "PlanService", "PlanCache"):
        assert required in repro.__all__, required


def test_unknown_attribute_raises():
    with pytest.raises(AttributeError, match="no attribute 'bogus'"):
        repro.bogus


# --------------------------------------------------------------------- #
# (c) tuner= is blessed; config= is a deprecated alias everywhere
# --------------------------------------------------------------------- #
def _small():
    spec = S.mttkrp(12, 8, 6, 4)
    csf = repro.build_csf(repro.random_sparse((12, 8, 6), 0.1, seed=0))
    rng = np.random.default_rng(0)
    factors = {"B": rng.standard_normal((8, 4)).astype(np.float32),
               "C": rng.standard_normal((6, 4)).astype(np.float32)}
    return spec, csf, factors


FAST = None  # built lazily so module import stays light


def _fast():
    global FAST
    if FAST is None:
        FAST = repro.TunerConfig(max_paths=2, max_candidates=2,
                                 orders_per_path=1, warmup=1, repeats=2)
    return FAST


def test_plan_config_alias_warns_and_both_is_an_error():
    spec, csf, factors = _small()
    with pytest.warns(DeprecationWarning, match=r"plan\(config=.*tuner="):
        via_alias = repro.plan(spec, config=_fast())
    assert via_alias == repro.plan(spec, tuner=_fast())
    with pytest.raises(ValueError, match="both tuner= and config="):
        repro.plan(spec, tuner=_fast(), config=_fast())


def test_tune_config_alias_warns_and_both_is_an_error():
    spec, csf, factors = _small()
    with pytest.warns(DeprecationWarning, match=r"tune\(config=.*tuner="):
        p1, s1 = repro.tune(spec, csf=csf, factors=factors, config=_fast())
    # the alias reached the search as the real config (measured timings
    # may crown different winners run to run, so compare behavior)
    assert s1.candidates_timed <= _fast().max_candidates
    assert isinstance(p1, repro.SpTTNPlan)
    with pytest.raises(ValueError, match="both tuner= and config="):
        repro.tune(spec, csf=csf, factors=factors,
                   tuner=_fast(), config=_fast())


def test_plan_service_rejects_both_spellings():
    with pytest.raises(ValueError, match="both tuner= and config="):
        repro.PlanService(tuner=_fast(), config=_fast())
    # either spelling alone works (config= stays accepted for back-compat)
    assert repro.PlanService(tuner=_fast()).config is _fast()
    assert repro.PlanService(config=_fast()).config is _fast()


# --------------------------------------------------------------------- #
# (d) unknown engine kwargs fail loudly, with the valid set named
# --------------------------------------------------------------------- #
def test_make_executor_rejects_unknown_kwargs():
    spec, csf, factors = _small()
    p = repro.plan(spec)
    with pytest.raises(ValueError) as ei:
        repro.make_executor(spec, p.path, p.order, blocks=128)
    msg = str(ei.value)
    assert "blocks" in msg
    for valid in ("block", "strategy", "tile_align"):
        assert valid in msg
    # pallas-only kwargs on a non-pallas backend are rejected, not ignored
    with pytest.raises(ValueError, match="Pallas backends"):
        repro.make_executor(spec, p.path, p.order, backend="xla", block=8)


def test_execute_plan_rejects_unknown_kwargs():
    spec, csf, factors = _small()
    p = repro.plan(spec, nnz_levels=csf.nnz_levels())
    arrays = repro.CSFArrays.from_csf(csf)
    with pytest.raises(ValueError, match="unknown argument"):
        repro.execute_plan(p, arrays, factors, strategies="fused")
    with pytest.raises(ValueError, match="Pallas backends"):
        repro.execute_plan(p, arrays, factors, tile_align=True)  # xla plan
    # the happy path still happy after the rejections
    out = repro.execute_plan(p, arrays, factors)
    assert np.asarray(out).shape == (12, 4)


# --------------------------------------------------------------------- #
# (e) plan JSON v6 through the facade: slice stamp round-trips, v5 dies
# --------------------------------------------------------------------- #
def test_v6_round_trip_and_v5_rejection():
    import json
    spec, csf, factors = _small()
    p = repro.plan(spec, nnz_levels=csf.nnz_levels())
    peak = repro.plan_peak_bytes(spec, p.path, p.order, csf.nnz_levels())
    stamped = repro.plan(spec, nnz_levels=csf.nnz_levels(),
                         memory_budget=peak // 2)
    assert stamped.slice_chunks > 1
    rt = repro.plan_from_json(repro.plan_to_json(stamped))
    assert rt == stamped
    assert (rt.slice_mode, rt.slice_chunks) == (stamped.slice_mode,
                                                stamped.slice_chunks)

    doc = json.loads(repro.plan_to_json(p))
    assert doc["version"] == 6
    doc["version"] = 5
    with pytest.raises(ValueError, match="unsupported plan version 5"):
        repro.plan_from_json(json.dumps(doc))
