"""Codegen edge cases (ISSUE 4 satellite): degenerate sparsity patterns
through every reduction lowering, and an empty shard through distributed
replay.

* zero-nnz operands — all strategies (row/segsum/fused/auto) must return
  exact zeros of the right shape, jit-compatible;
* single-segment layouts — every nonzero under one output row, so the
  VMEM accumulator is revisited by every block and reset exactly once;
* all-singleton-segment layouts — every fiber its own segment, the
  maximal-padding regime that drives the row/segsum decision apart;
* an empty shard in distributed replay — partitioning that leaves one
  shard with no nonzeros must tune/execute the rest and still sum to
  the exact global output.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import spec as S
from repro.core.executor import (CSFArrays, dense_oracle, execute_plan,
                                 reference_execute)
from repro.core.planner import plan
from repro.kernels.codegen import PallasPlanExecutor, segment_profile
from repro.sparse import build_csf
from repro.sparse.coo import from_coords
from tests.conftest import run_with_devices

STRATEGIES = ["row", "segsum", "fused", "auto"]


def _factors(spec, rng):
    return {t.name: rng.standard_normal(
        [spec.dims[i] for i in t.indices]).astype(np.float32)
        for t in spec.inputs if not t.is_sparse}


# --------------------------------------------------------------------- #
# zero-nnz operands
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_zero_nnz_operand_all_strategies(strategy):
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(from_coords(np.zeros((0, 3), np.int64),
                                np.zeros((0,), np.float32), (6, 7, 8)))
    assert csf.nnz == 0 and csf.nnz_levels() == {0: 1, 1: 0, 2: 0, 3: 0}
    arrays = CSFArrays.from_csf(csf)
    rng = np.random.default_rng(0)
    factors = {k: jnp.asarray(v) for k, v in _factors(spec, rng).items()}
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy=strategy)
    fn = jax.jit(lambda f: ex(arrays, f))
    out = np.asarray(fn(factors))
    assert out.shape == (6, 4)
    np.testing.assert_array_equal(out, np.zeros((6, 4), np.float32))


def test_zero_nnz_segment_profile():
    csf = build_csf(from_coords(np.zeros((0, 3), np.int64),
                                np.zeros((0,), np.float32), (6, 7, 8)))
    arrays = CSFArrays.from_csf(csf)
    prof = segment_profile(arrays, 3, 1)
    assert prof.nfib == 0 and prof.max_seg == 0 and prof.mean_seg == 0.0


# --------------------------------------------------------------------- #
# single-segment layouts: one output row owns every fiber
# --------------------------------------------------------------------- #
def _single_segment_csf():
    # all nonzeros share i=2: level-1 has ONE fiber, every leaf block
    # accumulates into the same VMEM row (reset exactly once)
    rng = np.random.default_rng(5)
    js, ks = np.meshgrid(np.arange(7), np.arange(8), indexing="ij")
    coords = np.stack([np.full(js.size, 2), js.ravel(), ks.ravel()], axis=1)
    keep = rng.random(len(coords)) < 0.6
    coords = coords[keep]
    vals = rng.standard_normal(len(coords)).astype(np.float32)
    return build_csf(from_coords(coords, vals, (6, 7, 8)))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_single_segment_layout(strategy):
    spec = S.mttkrp(6, 7, 8, 4)
    csf = _single_segment_csf()
    assert csf.nfib[1] == 1
    arrays = CSFArrays.from_csf(csf)
    rng = np.random.default_rng(1)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy=strategy)
    out = np.asarray(ex(arrays, factors))
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    np.testing.assert_allclose(out, ref, atol=1e-5, err_msg=strategy)


# --------------------------------------------------------------------- #
# all-singleton segments: every fiber its own output row
# --------------------------------------------------------------------- #
def _singleton_segment_csf():
    # distinct (i, j) per nonzero and one k each: every level-2 segment
    # holds exactly one leaf fiber, so block-per-segment padding is the
    # worst case the segsum lowering exists for
    coords = np.array([[i, j, (i + j) % 8]
                       for i in range(6) for j in range(7)])
    rng = np.random.default_rng(6)
    vals = rng.standard_normal(len(coords)).astype(np.float32)
    return build_csf(from_coords(coords, vals, (6, 7, 8)))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_all_singleton_segments(strategy):
    spec = S.mttkrp(6, 7, 8, 4)
    csf = _singleton_segment_csf()
    arrays = CSFArrays.from_csf(csf)
    prof = segment_profile(arrays, 3, 2)
    assert prof.max_seg == 1 and prof.nfib == prof.nseg
    rng = np.random.default_rng(2)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy=strategy)
    out = np.asarray(ex(arrays, factors))
    ref = reference_execute(spec, p.path, p.order, csf, factors)
    np.testing.assert_allclose(out, ref, atol=1e-5, err_msg=strategy)
    if strategy == "auto":
        # the worst-padding profile must steer auto away from row when
        # the decision formula says so — and whatever it picks is exact
        assert set(ex.stage_strategy.values()) <= {"row", "segsum"}


def test_single_nnz_fused_chain():
    """One nonzero: every level has one fiber, every segment is first
    AND last — the fused kernel's reset+accumulate+flush all fire in a
    single grid step."""
    spec = S.mttkrp(6, 7, 8, 4)
    csf = build_csf(from_coords(np.array([[1, 2, 3]]),
                                np.array([2.0], np.float32), (6, 7, 8)))
    arrays = CSFArrays.from_csf(csf)
    rng = np.random.default_rng(3)
    factors = _factors(spec, rng)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = PallasPlanExecutor(spec, p.path, p.order, block=8,
                            interpret=True, strategy="fused")
    out = np.asarray(ex(arrays, factors))
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-5)


# --------------------------------------------------------------------- #
# empty shard through distributed replay
# --------------------------------------------------------------------- #
def test_execute_plan_with_empty_shard():
    """Sharded execute_plan where one shard carries zero nonzeros: the
    empty shard contributes exact zeros and the sum stays exact."""
    spec = S.mttkrp(8, 6, 5, 4)
    coords = np.array([[i, j, k] for i in (0, 2, 4, 6)
                       for j in range(3) for k in range(2)])
    rng = np.random.default_rng(4)
    coo = from_coords(coords,
                      rng.standard_normal(len(coords)).astype(np.float32),
                      (8, 6, 5))
    csf = build_csf(coo)
    factors = _factors(spec, rng)
    from repro.distributed import partition_nonzeros
    parts = partition_nonzeros(coo, {0: 2})      # odd-i shard is empty
    assert parts[1].nnz == 0 and parts[0].nnz == coo.nnz
    shards = [CSFArrays.from_csf(build_csf(c)) for c in parts]
    p = plan(spec, nnz_levels=csf.nnz_levels())
    out = np.asarray(execute_plan(p, shards, factors))
    np.testing.assert_allclose(out, dense_oracle(spec, csf, factors),
                               atol=1e-5)


def test_distributed_replay_with_empty_shard(tmp_path):
    """make_distributed_tuned over a partition that leaves one shard
    empty: the shard is recorded with no plan, tuning covers only live
    shards, and replay still matches the single-device reference."""
    code = f"""
import numpy as np
import jax
import jax.numpy as jnp
from repro.autotune import TunerConfig
from repro.core import spec as S
from repro.core.executor import dense_oracle
from repro.distributed import make_distributed_tuned
from repro.sparse import build_csf
from repro.sparse.coo import from_coords

spec = S.mttkrp(8, 6, 5, 4)
coords = np.array([[i, j, k] for i in (0, 2, 4, 6)
                   for j in range(3) for k in range(2)])
rng = np.random.default_rng(4)
coo = from_coords(coords,
                  rng.standard_normal(len(coords)).astype(np.float32),
                  (8, 6, 5))
csf = build_csf(coo)
factors = {{t.name: jnp.asarray(rng.standard_normal(
    [spec.dims[i] for i in t.indices]).astype(np.float32))
    for t in spec.inputs if not t.is_sparse}}
mesh = jax.make_mesh((2,), ("data",))
cfg = TunerConfig(max_paths=2, max_candidates=1, orders_per_path=1,
                  warmup=1, repeats=2, backends=("pallas",))
ref = dense_oracle(spec, csf,
                   {{k: np.asarray(v) for k, v in factors.items()}})

# homogeneous pallas winner (one live shard) -> the stacked shard_map
# engine, whose empty slot is all padding and contributes zero
dist = make_distributed_tuned(spec, coo, mesh, {{0: "data"}},
                              cache_dir={str(tmp_path)!r}, tuner=cfg,
                              block=8)
assert dist.mode == "collective-pallas"
assert dist.nnz_per_shard == [coo.nnz, 0]
assert dist.shards[1].plan is None and dist.shards[1].stats is None
assert dist.shards[0].plan is not None
np.testing.assert_allclose(dist(factors), ref, atol=1e-5)

# shard-by-shard replay (prefer_collective=False) skips the empty shard
distr = make_distributed_tuned(spec, coo, mesh, {{0: "data"}},
                               cache_dir={str(tmp_path)!r}, tuner=cfg,
                               block=8, prefer_collective=False)
assert distr.mode == "replay"
assert distr.shards[1].fn is None
np.testing.assert_allclose(distr(factors), ref, atol=1e-5)
print("EMPTY-SHARD-OK")
"""
    out = run_with_devices(code, 2)
    assert "EMPTY-SHARD-OK" in out
