"""End-to-end driver: sparse CP decomposition via ALS, every MTTKRP planned
by the SpTTN framework (the paper's flagship application).

    PYTHONPATH=src python examples/cp_als.py [--steps 200]
    PYTHONPATH=src python examples/cp_als.py --autotune --cache-dir .plans
        # measured search per mode-permuted MTTKRP; a re-run (or any later
        # tensor with the same sparsity profile) loads the plans from disk
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import (COOTensor, CSFArrays, build_csf, make_executor, parse,
                   plan, random_sparse, tttp3)


def cp_als(coo: COOTensor, rank: int, steps: int, seed: int = 0,
           autotune: bool = False, cache_dir: str | None = None):
    I, J, K = coo.shape
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((I, rank)).astype(np.float32)) * .1
    B = jnp.asarray(rng.standard_normal((J, rank)).astype(np.float32)) * .1
    C = jnp.asarray(rng.standard_normal((K, rank)).astype(np.float32)) * .1

    # one planned MTTKRP per mode: permute storage so the output mode leads
    execs = {}
    for mode, name in ((0, "A"), (1, "B"), (2, "C")):
        perm = (mode,) + tuple(m for m in range(3) if m != mode)
        csf_m = build_csf(coo.permute_modes(perm))
        dims = dict(zip("ijk", csf_m.shape))
        spec = parse("ijk,ja,ka->ia", dims={**dims, "a": rank}, sparse=0,
                       names=["T", "F1", "F2"])
        p = plan(spec, nnz_levels=csf_m.nnz_levels(), autotune=autotune,
                 cache_dir=cache_dir, csf=csf_m)
        if autotune and p.stats is not None:
            how = "cache" if p.stats.cache_hit else (
                f"search ({p.stats.candidates_timed} timed)")
            print(f"mode {name}: plan from {how}", flush=True)
        ex = make_executor(spec, p.path, p.order)
        arrays = CSFArrays.from_csf(csf_m)
        execs[name] = jax.jit(
            lambda f1, f2, ex=ex, arrays=arrays: ex(
                arrays, {"F1": f1, "F2": f2}))

    # TTTP-style residual on the observed entries
    spec_r = tttp3(I, J, K, rank)
    csf = build_csf(coo)
    pr = plan(spec_r, nnz_levels=csf.nnz_levels(), autotune=autotune,
              cache_dir=cache_dir, csf=csf)
    exr = make_executor(spec_r, pr.path, pr.order)
    arrays_r = CSFArrays.from_csf(csf)
    vals = jnp.asarray(coo.values)

    import dataclasses
    ones_arrays = dataclasses.replace(arrays_r,
                                      values=jnp.ones_like(vals))

    @jax.jit
    def fit(A, B, C):
        """Standard sparse-CP fit = 1 - ||T - est||_F / ||T||_F, with
        ||est||^2 via the Hadamard-Gram identity (zeros included — sparse
        CP fits the zeros as true zeros, as in SPLATT)."""
        est_obs = exr(ones_arrays, {"U": A, "V": B, "W": C})
        t2 = jnp.sum(vals ** 2)
        cross = jnp.sum(vals * est_obs)
        gram = (A.T @ A) * (B.T @ B) * (C.T @ C)
        est2 = jnp.sum(gram)
        resid = jnp.sqrt(jnp.maximum(t2 - 2 * cross + est2, 0.0))
        return 1.0 - resid / jnp.sqrt(t2)

    def solve(mttkrp_out, F1, F2):
        G = (F1.T @ F1) * (F2.T @ F2) + 1e-6 * jnp.eye(rank)
        return jnp.linalg.solve(G, mttkrp_out.T).T

    hist = []
    for it in range(steps):
        A = solve(execs["A"](B, C), B, C)
        B = solve(execs["B"](A, C), A, C)
        C = solve(execs["C"](A, B), A, B)
        if it % 10 == 0 or it == steps - 1:
            r = float(fit(A, B, C))
            hist.append(r)
            print(f"iter {it:4d}  fit {r:.4f}", flush=True)
    return (A, B, C), hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--autotune", action="store_true",
                    help="measured loop-nest search instead of model-only")
    ap.add_argument("--cache-dir", default=None,
                    help="persist tuned plans here (skips re-search)")
    args = ap.parse_args()
    # synthesize a tensor with known rank-8 structure + noise
    rng = np.random.default_rng(1)
    I, J, K, r0 = 128, 96, 80, 8
    A0, B0, C0 = (rng.standard_normal((n, r0)) for n in (I, J, K))
    T = random_sparse((I, J, K), 5e-3, seed=2)
    vals = (A0[T.coords[:, 0]] * B0[T.coords[:, 1]]
            * C0[T.coords[:, 2]]).sum(1).astype(np.float32)
    T.values[:] = vals + 0.01 * rng.standard_normal(len(vals))
    t0 = time.time()
    _, hist = cp_als(T, rank=args.rank, steps=args.steps,
                     autotune=args.autotune, cache_dir=args.cache_dir)
    print(f"done in {time.time()-t0:.1f}s; fit {hist[0]:.3f} -> "
          f"{hist[-1]:.3f}")
    assert hist[-1] > hist[0]


if __name__ == "__main__":
    main()
