"""Serving example: slot-based continuous batching over a reduced model —
prefill + decode with a shared compiled decode step.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro.configs import get_reduced
from repro.models import model_init
from repro.serve.serve_step import Request, Server


def main():
    cfg = get_reduced("smollm-135m")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, slots=4, cache_len=64)
    rng = np.random.default_rng(0)
    for i in range(6):
        srv.submit(Request(prompt=rng.integers(
            0, cfg.vocab, 8).astype(np.int32), max_new=12))
    done = srv.run(max_steps=64)
    for i, r in enumerate(done):
        print(f"req{i}: prompt={r.prompt[:4]}... -> {r.out}")
    assert len(done) == 6 and all(len(r.out) >= 12 for r in done)
    print("served", len(done), "requests")


if __name__ == "__main__":
    main()
