"""Quickstart: declare an SpTTN kernel, let the planner find the minimum
cost loop nest, execute it, and inspect the schedule.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import spec as S
from repro.core.planner import plan
from repro.core.executor import CSFArrays, VectorizedExecutor, dense_oracle
from repro.sparse import build_csf, random_sparse

# MTTKRP (paper Eq. 1): A(i,a) = sum_jk T(i,j,k) B(j,a) C(k,a)
I, J, K, R = 256, 128, 64, 32
spec = S.mttkrp(I, J, K, R)

T = random_sparse((I, J, K), density=1e-3, seed=0)
csf = build_csf(T)
print(f"T: shape={T.shape} nnz={T.nnz} "
      f"nnz^(IJ)={csf.nnz_level(2)} nnz^(I)={csf.nnz_level(1)}")

# plan: enumerate min-depth contraction paths, run Algorithm 1 per path
p = plan(spec, nnz_levels=csf.nnz_levels())
print("\nchosen loop nest (factorize-and-fuse):")
print(p.describe())

rng = np.random.default_rng(0)
factors = {"B": jnp.asarray(rng.standard_normal((J, R)).astype(np.float32)),
           "C": jnp.asarray(rng.standard_normal((K, R)).astype(np.float32))}
out = VectorizedExecutor(spec, p.path, p.order)(CSFArrays.from_csf(csf),
                                                factors)
oracle = dense_oracle(spec, csf, {k: np.asarray(v)
                                  for k, v in factors.items()})
print("\nmax |out - dense einsum oracle| =",
      float(np.abs(np.asarray(out) - oracle).max()))
