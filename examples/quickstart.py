"""Quickstart: declare an SpTTN kernel, let the planner find the minimum
cost loop nest, execute it, and inspect the schedule — all through the
top-level ``repro`` facade.

    PYTHONPATH=src python examples/quickstart.py
    EX_SCALE=0.1 PYTHONPATH=src python examples/quickstart.py   # CI smoke
"""
import os

import numpy as np

import jax.numpy as jnp

from repro import (CSFArrays, build_csf, dense_oracle, execute_plan,
                   make_executor, mttkrp, plan, plan_peak_bytes,
                   random_sparse)

# MTTKRP (paper Eq. 1): A(i,a) = sum_jk T(i,j,k) B(j,a) C(k,a)
SCALE = float(os.environ.get("EX_SCALE", "1.0"))
I, J, K, R = (max(8, int(n * SCALE)) for n in (256, 128, 64, 32))
spec = mttkrp(I, J, K, R)

T = random_sparse((I, J, K), density=1e-3, seed=0)
csf = build_csf(T)
print(f"T: shape={T.shape} nnz={T.nnz} "
      f"nnz^(IJ)={csf.nnz_level(2)} nnz^(I)={csf.nnz_level(1)}")

# plan: enumerate min-depth contraction paths, run Algorithm 1 per path
p = plan(spec, nnz_levels=csf.nnz_levels())
print("\nchosen loop nest (factorize-and-fuse):")
print(p.describe())

rng = np.random.default_rng(0)
factors = {"B": jnp.asarray(rng.standard_normal((J, R)).astype(np.float32)),
           "C": jnp.asarray(rng.standard_normal((K, R)).astype(np.float32))}
arrays = CSFArrays.from_csf(csf)
out = make_executor(spec, p.path, p.order)(arrays, factors)
oracle = dense_oracle(spec, csf, {k: np.asarray(v)
                                  for k, v in factors.items()})
err = float(np.abs(np.asarray(out) - oracle).max())
print("\nmax |out - dense einsum oracle| =", err)
assert err < 1e-3

# out-of-core replay (docs/out-of-core.md): cap the working set at half
# the unsliced peak and the same plan streams chunk by chunk, exactly
peak = plan_peak_bytes(spec, p.path, p.order, csf.nnz_levels())
sliced = execute_plan(p, arrays, factors, memory_budget=peak // 2)
print(f"peak working set {peak} B; replayed under {peak // 2} B budget, "
      f"max delta = {float(np.abs(np.asarray(sliced) - oracle).max()):.2e}")
assert np.allclose(np.asarray(sliced), np.asarray(out), atol=1e-4)
