"""Tensor completion with SGD on observed entries: the gradient's
cost-dominant kernels are TTTP (residual, Eq. 3) and MTTKRP-like products
(paper §3) — all planned by the framework.

    PYTHONPATH=src python examples/tensor_completion.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import (CSFArrays, build_csf, make_executor, plan,
                   random_sparse, tttp3)


def main(steps: int = 300, rank: int = 12, lr: float = 0.05):
    I, J, K = 96, 80, 64
    rng = np.random.default_rng(0)
    A0, B0, C0 = (rng.standard_normal((n, rank)).astype(np.float32) * 0.5
                  for n in (I, J, K))
    omega = random_sparse((I, J, K), 8e-3, seed=4)   # observed entries
    truth = (A0[omega.coords[:, 0]] * B0[omega.coords[:, 1]]
             * C0[omega.coords[:, 2]]).sum(1)
    csf = build_csf(omega)
    arrays = CSFArrays.from_csf(csf)
    obs = jnp.asarray(truth)

    spec = tttp3(I, J, K, rank)
    p = plan(spec, nnz_levels=csf.nnz_levels())
    ex = make_executor(spec, p.path, p.order)
    import dataclasses
    ones_arrays = dataclasses.replace(arrays,
                                      values=jnp.ones_like(arrays.values))

    def loss(params):
        A, B, C = params
        est = ex(ones_arrays, {"U": A, "V": B, "W": C})
        return 0.5 * jnp.mean((est - obs) ** 2)

    params = tuple(jnp.asarray(rng.standard_normal((n, rank))
                               .astype(np.float32)) * 0.4
                   for n in (I, J, K))
    val_grad = jax.jit(jax.value_and_grad(loss))
    m = tuple(jnp.zeros_like(p_) for p_ in params)
    vv = tuple(jnp.zeros_like(p_) for p_ in params)
    v0 = None
    for it in range(steps):
        v, g = val_grad(params)
        v0 = float(v) if v0 is None else v0
        m = tuple(0.9 * m_ + 0.1 * g_ for m_, g_ in zip(m, g))
        vv = tuple(0.99 * v_ + 0.01 * g_ * g_ for v_, g_ in zip(vv, g))
        t = it + 1
        params = tuple(
            p_ - lr * (m_ / (1 - 0.9 ** t))
            / (jnp.sqrt(v_ / (1 - 0.99 ** t)) + 1e-8)
            for p_, m_, v_ in zip(params, m, vv))
        if it % 25 == 0 or it == steps - 1:
            print(f"step {it:4d}  mse {float(v):.5f}", flush=True)
    assert float(v) < 0.25 * v0, (float(v), v0)
    print("completion converged")


if __name__ == "__main__":
    main()
