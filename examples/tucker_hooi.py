"""Tucker decomposition via HOOI: the TTMc kernel (paper Eq. 2) planned and
executed by the framework, one mode-permuted CSF per mode (as SPLATT does).

    PYTHONPATH=src python examples/tucker_hooi.py [--autotune]
        [--cache-dir .plans]
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro import (CSFArrays, build_csf, make_executor, parse, plan,
                   random_sparse)


def main(steps: int = 8, ranks=(8, 6, 4), autotune: bool = False,
         cache_dir: str | None = None):
    I, J, K = 96, 80, 64
    T = random_sparse((I, J, K), 5e-3, seed=3)
    rng = np.random.default_rng(0)
    U = [jnp.linalg.qr(jnp.asarray(rng.standard_normal((n, r))
                                   .astype(np.float32)))[0]
         for n, r in zip((I, J, K), ranks)]

    execs = []
    for mode in range(3):
        perm = (mode,) + tuple(m for m in range(3) if m != mode)
        csf_m = build_csf(T.permute_modes(perm))
        dims = dict(zip("ijk", csf_m.shape))
        r1, r2 = [ranks[m] for m in perm[1:]]
        spec = parse("ijk,jr,ks->irs",
                       dims={**dims, "r": r1, "s": r2}, sparse=0,
                       names=["T", "U1", "U2"])
        p = plan(spec, nnz_levels=csf_m.nnz_levels(), autotune=autotune,
                 cache_dir=cache_dir, csf=csf_m)
        if autotune and p.stats is not None:
            how = "cache" if p.stats.cache_hit else (
                f"search ({p.stats.candidates_timed} timed)")
            print(f"mode {mode}: plan from {how}", flush=True)
        ex = make_executor(spec, p.path, p.order)
        arrays = CSFArrays.from_csf(csf_m)
        execs.append(jax.jit(
            lambda u1, u2, ex=ex, arrays=arrays: ex(
                arrays, {"U1": u1, "U2": u2})))

    for it in range(steps):
        for mode in range(3):
            others = [m for m in range(3) if m != mode]
            Y = execs[mode](U[others[0]], U[others[1]])   # (I_m, r1, r2)
            Ym = np.asarray(Y).reshape(Y.shape[0], -1)
            u, s, _ = np.linalg.svd(Ym, full_matrices=False)
            U[mode] = jnp.asarray(u[:, : ranks[mode]])
        core_norm = float(np.linalg.norm(s[: ranks[2]]))
        print(f"sweep {it}: captured core norm {core_norm:.4f}", flush=True)
    print("HOOI done")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--autotune", action="store_true",
                    help="measured loop-nest search instead of model-only")
    ap.add_argument("--cache-dir", default=None,
                    help="persist tuned plans here (skips re-search)")
    args = ap.parse_args()
    main(steps=args.steps, autotune=args.autotune,
         cache_dir=args.cache_dir)
