"""End-to-end LM training driver on CPU: a reduced SmolLM-family model,
full framework path (data pipeline -> sharded-capable train step ->
checkpointing).  ~200 steps, loss printed every 20.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch smollm-135m]
"""
import argparse
import time

import jax

from repro.configs import get_reduced
from repro.configs.base import RunConfig
from repro.data.pipeline import make_loader
from repro.models import model_init
from repro.train import checkpoint as ckpt
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    n = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} (reduced) params={n/1e6:.2f}M")

    run = RunConfig(model=cfg, remat=False, learning_rate=3e-3,
                    warmup_steps=20)
    step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
    state = init_train_state(params)
    ds, _ = make_loader(cfg.vocab, args.seq, args.batch)

    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore(state, args.ckpt_dir)
        print(f"resumed from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        state, m = step(state, ds.batch_at(i))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{(i - start + 1) / (time.time() - t0):.1f} it/s",
                  flush=True)
        if (i + 1) % 100 == 0:
            ckpt.save(state, args.ckpt_dir, step=i + 1)
    print("done")


if __name__ == "__main__":
    main()
