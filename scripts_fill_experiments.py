"""Fill EXPERIMENTS.md placeholder markers from dryrun_results.json."""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.report import dryrun_table, roofline_table  # noqa: E402

results = json.load(open("dryrun_results.json"))
md = open("EXPERIMENTS.md").read()

dr = dryrun_table(results)
rl = (roofline_table(results, "16x16")
      + "\n\n### multi-pod 2x16x16 (shardability proof + scaling check)\n\n"
      + roofline_table(results, "2x16x16"))

assert "<!-- DRYRUN_TABLE -->" in md and "<!-- ROOFLINE_TABLE -->" in md
md = md.replace("<!-- DRYRUN_TABLE -->", dr)
md = md.replace("<!-- ROOFLINE_TABLE -->", rl)
open("EXPERIMENTS.md", "w").write(md)
print("EXPERIMENTS.md tables filled:",
      len([r for r in results if "roofline" in r]), "cells")
