"""Serving driver: host-mesh sharded decode loop (see examples/serve_lm.py
for the single-host version)."""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config, get_reduced
from repro.models import model_init
from repro.serve.serve_step import Request, Server


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, slots=4, cache_len=128)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        srv.submit(Request(prompt=rng.integers(0, cfg.vocab, 12)
                           .astype(np.int32), max_new=args.max_new))
    done = srv.run(max_steps=256)
    print(f"served {len(done)}/{args.requests} requests")


if __name__ == "__main__":
    main()
