import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count on first
# initialization, and the dry-run needs 512 placeholder CPU devices to build
# the production mesh.  (Smoke tests / benches do NOT import this module.)

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# For each cell this lowers the real step function (train_step / prefill /
# decode_step) with ShapeDtypeStruct inputs (no allocation), compiles it for
# the 16x16 single-pod and 2x16x16 multi-pod meshes, and records:
#   * compiled.memory_analysis()  — bytes per device (proves it fits),
#   * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline,
#   * collective operand bytes parsed from the optimized HLO (with scan-body
#     trip-count multiplicity) — the collective roofline term.
#
# Usage:
#   python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] --out results.json

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SHAPES, RunConfig, get_config, input_specs,
                           shape_applicable)
from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (analytic_memory, collective_bytes_from_hlo,
                                   roofline_terms, summarize_cost)
from repro.models import transformer as T
from repro.train.train_step import init_train_state, make_train_step


def abstract_params(cfg: ModelConfig):
    """(params ShapeDtypeStructs, logical-axis specs) without allocating.

    The specs tree is plain python (tuples of strings) built during the
    traced init; it escapes via a side channel since eval_shape outputs
    must be arrays."""
    box = {}

    def init(k):
        p, s = T.model_init(k, cfg)
        box["specs"] = s
        return p

    params_shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
    return params_shapes, box["specs"]


def _lower(cfg: ModelConfig, sc, mesh, rules, kv_dtype=jnp.bfloat16,
           unroll: bool = False):
    """Lower the cell's real step function with ShapeDtypeStruct inputs."""
    run = RunConfig(model=cfg, microbatches=1, scan_unroll=unroll)
    params_shapes, specs = abstract_params(cfg)
    params_sh = SH.tree_sharding(params_shapes, specs, rules, mesh)
    if sc.kind == "train":
        state_shapes = jax.eval_shape(
            lambda p: init_train_state(p), params_shapes)
        state_sh = _state_sharding(state_shapes, params_sh, mesh)
        batch_shapes = input_specs(cfg, sc)
        batch_sh = _batch_sharding(batch_shapes, rules, mesh)
        step = make_train_step(cfg, run)
        with SH.mesh_context(mesh, rules):
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                donate_argnums=(0,)).lower(state_shapes, batch_shapes)
    elif sc.kind == "prefill":
        batch_shapes = input_specs(cfg, sc)
        batch_sh = _batch_sharding(batch_shapes, rules, mesh)

        def pre(params, batch):
            return T.prefill(params, cfg, batch, remat=True, unroll=unroll)

        with SH.mesh_context(mesh, rules):
            lowered = jax.jit(
                pre, in_shardings=(params_sh, batch_sh)).lower(
                params_shapes, batch_shapes)
    else:  # decode
        B, S = sc.global_batch, sc.seq_len
        cache_shapes = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S, kv_dtype))
        cache_sh = _cache_sharding(cache_shapes, rules, mesh, B, S)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        enc_shapes = None
        if cfg.encdec:
            enc_shapes = jax.ShapeDtypeStruct((B, S // 4, cfg.d_model),
                                              cfg.compute_dtype)

        def dec(params, caches, tokens, p, enc_out=None):
            return T.decode_step(params, cfg, caches, tokens, p,
                                 enc_out=enc_out, unroll=unroll)

        with SH.mesh_context(mesh, rules):
            args = [params_shapes, cache_shapes, tok, pos]
            in_sh = [params_sh, cache_sh, SH.NamedSharding(mesh, SH.P()),
                     SH.NamedSharding(mesh, SH.P())]
            if enc_shapes is not None:
                args.append(enc_shapes)
                in_sh.append(SH.NamedSharding(mesh, SH.P()))
            lowered = jax.jit(
                dec, in_shardings=tuple(in_sh),
                donate_argnums=(1,)).lower(*args)
    return lowered


def _probe_cfg(cfg: ModelConfig, n_groups: int) -> ModelConfig:
    """Variant with exactly ``n_groups`` scanned groups (head/tail intact in
    structure, tail dropped) — used to measure per-group HLO cost exactly
    via the difference of two compiles (XLA counts while bodies once)."""
    g = len(cfg.block_pattern)
    head = cfg.moe.first_dense if cfg.moe is not None else 0
    kw = {"n_layers": head + n_groups * g}
    if cfg.encdec:
        kw["n_enc_layers"] = n_groups
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape: str, multi_pod: bool,
               kv_dtype=jnp.bfloat16, probe: bool = True,
               preset: str = "2d", cfg_override=None):
    cfg = cfg_override or get_config(arch)
    sc = SHAPES[shape]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": why,
                "mesh": "2x16x16" if multi_pod else "16x16"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    seq_shard = sc.kind == "decode" and sc.global_batch < mesh.shape["data"]
    rules = SH.default_rules(multi_pod, sc.kind, seq_shard=seq_shard,
                             preset=preset)

    t0 = time.time()
    lowered = _lower(cfg, sc, mesh, rules, kv_dtype)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = summarize_cost(compiled.cost_analysis())
    res = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "preset": preset,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": cost,
        "memory": _mem_dict(mem),
    }

    # exact loop-body correction: XLA's cost_analysis counts a while body
    # once; compile 2-group and 3-group probes and take the difference
    from repro.models.transformer import layer_plan
    G = layer_plan(cfg, decoder=True).n_groups
    if probe and G > 1:
        try:
            # probes UNROLL the scan so every group is counted, then the
            # 3-group minus 2-group difference is exactly one group's cost
            comp2 = _lower(_probe_cfg(cfg, 2), sc, mesh, rules, kv_dtype,
                           unroll=True).compile()
            comp3 = _lower(_probe_cfg(cfg, 3), sc, mesh, rules, kv_dtype,
                           unroll=True).compile()
            c2 = summarize_cost(comp2.cost_analysis())
            c3 = summarize_cost(comp3.cost_analysis())
            body = {k: max(c3.get(k, 0.0) - c2.get(k, 0.0), 0.0)
                    for k in c3}
            res["cost_corrected"] = {
                k: c2.get(k, 0.0) + (G - 2) * body.get(k, 0.0)
                for k in c2}
            res["probe_body"] = body
            # collective bytes, probe-exact (no trip-count heuristic)
            w2 = collective_bytes_from_hlo(comp2.as_text(), [])
            w3 = collective_bytes_from_hlo(comp3.as_text(), [])
            body_wire = max(w3["wire_bytes"] - w2["wire_bytes"], 0)
            res["collectives_probe"] = {
                "wire_bytes": w2["wire_bytes"] + (G - 2) * body_wire,
                "per_group_wire_bytes": body_wire,
                "per_op_bytes_2g": w2["per_op_bytes"],
            }
        except Exception as e:
            res["cost_corrected"] = {"error": str(e)[:300]}
    else:
        res["cost_corrected"] = dict(cost)

    try:
        scan_trips = _scan_trip_counts(cfg)
        res["collectives"] = collective_bytes_from_hlo(
            compiled.as_text(), scan_trips)
    except Exception as e:  # HLO text can be very large; stay robust
        res["collectives"] = {"error": str(e)[:200]}
    res["analytic_memory"] = analytic_memory(cfg, sc, n_dev, multi_pod)
    res["roofline"] = roofline_terms(res, cfg, sc, n_dev)
    return res


def _state_sharding(state_shapes, params_sh, mesh):
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState
    rep = SH.NamedSharding(mesh, SH.P())
    return TrainState(
        params=params_sh,
        opt=AdamWState(m=params_sh, v=params_sh, step=rep))


def _batch_sharding(batch_shapes, rules, mesh):
    dataxes = rules["act_batch"]
    out = {}
    for k, v in batch_shapes.items():
        parts = [dataxes] + [None] * (len(v.shape) - 1)
        out[k] = SH.NamedSharding(mesh, SH.P(*parts))
    return out


def _cache_sharding(cache_shapes, rules, mesh, B: int, S: int):
    """KV/state cache shardings.  Batched decode: shard the batch axis over
    the data axes; long-context (batch < data axis): shard the sequence
    axis instead (flash-decode partial-softmax, psum'd by GSPMD)."""
    data = rules["act_batch"]
    seq = rules.get("act_seq")
    axes = (data,) if isinstance(data, str) else tuple(data)
    d_extent = 1
    for a in axes:
        d_extent *= mesh.shape[a]

    def one(leaf):
        sizes = leaf.shape
        parts = [None] * len(sizes)
        if seq is not None:
            for ax, sz in enumerate(sizes):
                if sz == S and sz % mesh.shape[seq] == 0:
                    parts[ax] = seq
                    break
        else:
            for ax, sz in enumerate(sizes):
                if sz == B and sz % d_extent == 0:
                    parts[ax] = data
                    break
        return SH.NamedSharding(mesh, SH.P(*parts))

    return jax.tree.map(one, cache_shapes)


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _scan_trip_counts(cfg: ModelConfig) -> list[int]:
    """Candidate loop trip counts for scan-body collective multiplicity."""
    from repro.models.transformer import layer_plan
    plan = layer_plan(cfg, decoder=True)
    trips = [plan.n_groups]
    if cfg.encdec:
        trips.append(cfg.n_enc_layers)
    return [t for t in trips if t > 1]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--preset", default="2d")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            print(f"=== {arch} x {shape} x "
                  f"{'2x16x16' if mp else '16x16'} ===", flush=True)
            try:
                res = lower_cell(arch, shape, mp, preset=args.preset)
            except Exception as e:
                import traceback
                traceback.print_exc()
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}"[:500]}
            print(json.dumps(res, indent=1, default=str)[:2000], flush=True)
            results.append(res)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
    return results


if __name__ == "__main__":
    main()
