# launch layer: mesh construction, multi-pod dry-run, roofline analysis,
# train/serve drivers.  NOTE: import repro.launch.dryrun only in dedicated
# processes — it sets XLA_FLAGS to 512 fake devices at import time.
from repro.launch import mesh

__all__ = ["mesh"]
