"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e target):
  PEAK_FLOPS = 197e12 bf16 FLOP/s per chip
  HBM_BW     = 819e9  B/s per chip
  ICI_BW     = 50e9   B/s per link (3D-torus; ~2 usable links per transfer
               direction on a 16x16 slice — we charge 1 link per collective
               stream, the conservative bound)

Terms (seconds, per step, per chip):
  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = wire_bytes / ICI_BW

cost_analysis() reports per-partition numbers for SPMD executables; while
loops (our layer scans) count their body ONCE, so both FLOPs and collective
bytes found inside scan bodies are multiplied by the known trip count
(configs are static — trip counts are exact, not heuristic).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_COMP_RE = re.compile(r"^\s*(%?[\w.-]+)\s+\([^)]*\)\s*->", re.M)
_WHILE_BODY_RE = re.compile(r"body=%?([\w.-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo: str, scan_trips: list[int]) -> dict:
    """Sum collective payload bytes from optimized HLO text.

    Collectives inside a computation referenced as a while-loop body are
    multiplied by the scan trip count (matched greedily to the known trip
    counts; an unmatched body gets multiplicity max(trips) to stay
    conservative).  all-reduce wire bytes are charged 2x payload (ring).
    """
    # map computation name -> list of (op, bytes)
    per_comp: dict[str, list[tuple[str, int]]] = {}
    comp = "__entry__"
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.startswith(("ENTRY ", "%fused", "HloModule")):
            pass
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.-]+)\s+\([^)]*\)\s*->", stripped)
        if m and ("{" in stripped or stripped.endswith("->")):
            comp = m.group(1)
        cm = _COLL_RE.search(stripped)
        if cm:
            _, dtype, dims, op, _ = cm.groups()
            b = _shape_bytes(dtype, dims)
            per_comp.setdefault(comp, []).append((op, b))
    # find while bodies
    bodies = set(_WHILE_BODY_RE.findall(hlo))
    mult = max(scan_trips) if scan_trips else 1
    totals: dict[str, float] = {}
    wire = 0.0
    for comp_name, items in per_comp.items():
        k = mult if any(comp_name.startswith(b) or b.startswith(comp_name)
                        for b in bodies) else 1
        for op, b in items:
            factor = 2.0 if op == "all-reduce" else 1.0
            totals[op] = totals.get(op, 0.0) + k * b
            wire += k * b * factor
    return {"per_op_bytes": {k: int(v) for k, v in totals.items()},
            "wire_bytes": int(wire),
            "scan_multiplier": mult,
            "n_collectives": sum(len(v) for v in per_comp.values())}


def summarize_cost(cost) -> dict:
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    return out


def model_flops(cfg, sc) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for dense (N_active for MoE) per step,
    plus a per-kind mixing term: S^2 attention (windowed for 'local'
    layers), O(S) latent-cache attention for MLA decode, O(K^2) recurrent
    state updates for RG-LRU/RWKV."""
    n_active = active_params(cfg)
    tokens = sc.global_batch * (sc.seq_len if sc.kind != "decode" else 1)
    base = (6.0 if sc.kind == "train" else 2.0) * n_active * tokens
    hd = cfg.hd
    S = sc.seq_len
    B = sc.global_batch
    bwd = 3.0 if sc.kind == "train" else 1.0
    kinds = cfg.pattern_for_layers()
    mix = 0.0
    w = min(cfg.window or S, S)
    for kind in kinds:
        if kind in ("attn", "xattn", "local"):
            span = w if kind == "local" else S
            if sc.kind == "decode":
                if cfg.mla is not None:
                    # absorbed MLA: scores+ctx read the compressed latent
                    m = cfg.mla
                    mix += 4.0 * B * cfg.n_heads * span * \
                        (m.kv_lora + m.qk_rope_dim)
                else:
                    mix += 4.0 * B * span * cfg.n_kv_heads * hd
            else:
                mix += bwd * 2.0 * 2.0 * B * S * span * cfg.n_heads * hd
        elif kind == "rglru":
            mix += bwd * 2.0 * B * (S if sc.kind != "decode" else 1) \
                * cfg.d_model * 4
        elif kind == "rwkv":
            K = 64
            steps = S if sc.kind != "decode" else 1
            mix += bwd * 2.0 * B * steps * (cfg.d_model // 64) * K * K * 3
    return base + mix


def active_params(cfg) -> float:
    """Parameter count active per token (MoE counts top_k experts)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.hd
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    kinds = cfg.pattern_for_layers()
    for i, kind in enumerate(kinds):
        if kind in ("attn", "local", "xattn"):
            if cfg.mla is not None:
                m = cfg.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                per_layer += (d * m.q_lora + m.q_lora * cfg.n_heads * qk
                              + d * (m.kv_lora + m.qk_rope_dim)
                              + m.kv_lora * cfg.n_heads *
                              (m.qk_nope_dim + m.v_head_dim)
                              + cfg.n_heads * m.v_head_dim * d)
            else:
                per_layer += (cfg.n_heads * hd * d * 2
                              + cfg.n_kv_heads * hd * d * 2)
            if kind == "xattn":
                per_layer += (cfg.n_heads * hd * d * 2
                              + cfg.n_kv_heads * hd * d * 2)
        elif kind == "rglru":
            per_layer += 7 * d * d / 1  # in/gate/out + gates (approx exact)
        elif kind == "rwkv":
            per_layer += 5 * d * d + 2 * d * cfg.d_ff
        # ffn
        if kind != "rwkv":
            if cfg.moe is not None and i >= cfg.moe.first_dense:
                mo = cfg.moe
                per_layer += 3 * d * mo.d_expert * mo.top_k
                per_layer += 3 * d * mo.n_shared * mo.d_shared
                per_layer += d * mo.n_experts  # router
            elif cfg.moe is not None and i < cfg.moe.first_dense:
                per_layer += 3 * d * cfg.moe.d_first_dense
            else:
                mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
                per_layer += mult * d * cfg.d_ff
    enc = 0.0
    if cfg.encdec:
        enc = cfg.n_enc_layers * (4 * d * d + (2 if cfg.mlp == "gelu" else 3)
                                  * d * cfg.d_ff)
    return emb + per_layer + enc


def total_params(cfg) -> float:
    """All parameters (MoE counts every expert)."""
    if cfg.moe is None:
        return active_params(cfg)
    mo = cfg.moe
    d = cfg.d_model
    n_moe_layers = cfg.n_layers - mo.first_dense
    delta = 3 * d * mo.d_expert * (mo.n_experts - mo.top_k) * n_moe_layers
    return active_params(cfg) + delta


def analytic_memory(cfg, sc, n_dev: int, multi_pod: bool) -> dict:
    """Per-device HBM bytes, assuming the TPU fused-attention emitter (the
    XLA-CPU backend materializes full attention logits, so its temp report
    is an upper bound — this is the fits-proof for the 16 GiB v5e budget)."""
    n_total = total_params(cfg)
    d_model = cfg.d_model
    model_shards = 16  # model axis extent on both meshes
    data_shards = n_dev // model_shards
    p_bytes = 2 * n_total / n_dev          # bf16 params, fully sharded
    opt_bytes = 8 * n_total / n_dev        # fp32 m+v
    grad_bytes = 4 * n_total / n_dev       # fp32 grads (transient)
    act = cache = 0.0
    if sc.kind == "train":
        toks_per_dev = sc.global_batch * sc.seq_len / data_shards
        L = cfg.n_layers
        act = toks_per_dev * d_model * 2 * (L + 2)   # remat boundaries bf16
        act += toks_per_dev * cfg.vocab * 4 / model_shards  # fp32 logits
    elif sc.kind == "prefill":
        toks_per_dev = sc.global_batch * sc.seq_len / data_shards
        act = toks_per_dev * d_model * 2 * (cfg.n_layers + 2)
        cache = _cache_bytes(cfg, sc) / n_dev
    else:
        cache = _cache_bytes(cfg, sc) / n_dev
        act = sc.global_batch * d_model * 2 * cfg.n_layers
    total = p_bytes + opt_bytes * (sc.kind == "train") \
        + grad_bytes * (sc.kind == "train") + act + cache
    return {"params_B": int(p_bytes), "opt_B": int(opt_bytes),
            "act_B": int(act), "cache_B": int(cache),
            "total_per_dev_B": int(total),
            "fits_16GiB": bool(total < 16 * 2 ** 30)}


def _cache_bytes(cfg, sc) -> float:
    B, S = sc.global_batch, sc.seq_len
    per_tok = 0.0
    kinds = cfg.pattern_for_layers()
    for kind in kinds:
        if kind == "attn" or kind == "xattn":
            if cfg.mla is not None:
                per_tok += 2 * (cfg.mla.kv_lora + cfg.mla.qk_rope_dim)
            else:
                per_tok += 2 * 2 * cfg.n_kv_heads * cfg.hd
        elif kind == "local":
            w = min(cfg.window or S, S)
            per_tok += 2 * 2 * cfg.n_kv_heads * cfg.hd * (w / S)
        elif kind in ("rglru", "rwkv"):
            pass  # O(1) state per sequence, counted below
    state = 0.0
    for kind in kinds:
        if kind == "rglru":
            state += 4 * cfg.d_model * 2
        elif kind == "rwkv":
            state += (cfg.d_model // 64) * 64 * 64 * 4 + 2 * cfg.d_model * 4
    return B * S * per_tok + B * state


def roofline_terms(res: dict, cfg, sc, n_dev: int) -> dict:
    cost = res.get("cost_corrected") or res.get("cost", {})
    if "error" in cost:
        cost = res.get("cost", {})
    coll = res.get("collectives", {})
    hlo_flops = cost.get("flops", 0.0)
    hlo_bytes = cost.get("bytes_accessed", 0.0)
    wire = coll.get("wire_bytes", 0) if isinstance(coll, dict) else 0
    mf = model_flops(cfg, sc)
    terms = {
        "compute_s": hlo_flops / PEAK_FLOPS,
        "memory_s": hlo_bytes / HBM_BW,
        "collective_s": wire / ICI_BW,
        "model_flops_total": mf,
        "model_flops_per_dev": mf / n_dev,
        "hlo_flops_per_dev": hlo_flops,
        "useful_flops_ratio": (mf / n_dev) / hlo_flops if hlo_flops else None,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    return terms
