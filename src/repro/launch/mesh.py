"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (dryrun.py must set XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
