"""Cluster training driver: mesh + sharded state + checkpoint/restart +
straggler monitor.  On this container it runs with a host mesh
(XLA_FLAGS device count); on a real fleet the same code path runs per
process with jax.distributed.initialize().

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 30 --mesh 2x2
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_reduced
from repro.configs.base import RunConfig
from repro.data.pipeline import make_loader
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import model_init
from repro.train import checkpoint as ckpt
from repro.train.fault import StragglerMonitor, guarded_step
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="auto")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.mesh == "auto":
        mesh = make_host_mesh()
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    rules = SH.default_rules(False, "train")

    params, specs = model_init(jax.random.PRNGKey(0), cfg)
    psh = SH.tree_sharding(params, specs, rules, mesh)
    params = jax.device_put(params, psh)
    state = init_train_state(params)
    run = RunConfig(model=cfg, remat=True)

    with SH.mesh_context(mesh, rules):
        step = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))
        ds, _ = make_loader(cfg.vocab, args.seq, args.batch)
        start = ckpt.latest_step(args.ckpt_dir) or 0
        if start:
            state, start = ckpt.restore(state, args.ckpt_dir)
            print(f"resumed at {start}")
        mon = StragglerMonitor()
        for i in range(start, args.steps):
            t0 = time.time()
            state, m = guarded_step(step, state, ds.batch_at(i))
            dt = time.time() - t0
            if mon.observe(dt):
                print(f"step {i}: straggler flagged ({dt:.2f}s)")
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):.4f} "
                      f"({dt:.2f}s)", flush=True)
            if (i + 1) % args.ckpt_every == 0:
                ckpt.save(state, args.ckpt_dir, step=i + 1)
    print("train driver done")


if __name__ == "__main__":
    main()
