"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
dryrun_results.json (idempotent; §Perf and narrative sections are
hand-written in EXPERIMENTS.md and preserved)."""
from __future__ import annotations

import json
import sys


def _f(x, nd=3):
    return f"{x:.{nd}f}" if isinstance(x, (int, float)) else str(x)


def _gib(b):
    return f"{b / 2**30:.2f}"


def _arch_label(r: dict) -> str:
    preset = r.get("preset", "2d")
    return r["arch"] if preset in (None, "2d") else \
        f"{r['arch']} [{preset}]"


def dryrun_table(results: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compile_s | HLO GFLOP/dev | coll GB/dev "
        "| args MB/dev | analytic mem/dev GiB | fits 16GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} | — | — "
                f"| — | — | — | skipped: {r['skipped'][:40]} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| ERROR {r['error'][:60]} | | | | | |")
            continue
        cost = r.get("cost_corrected") or r.get("cost", {})
        coll = r.get("collectives_probe") or r.get("collectives", {})
        am = r.get("analytic_memory", {})
        mem = r.get("memory") or {}
        args_mb = (mem.get("argument_size_in_bytes", 0)) / 2**20
        lines.append(
            f"| {_arch_label(r)} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']} "
            f"| {cost.get('flops', 0) / 1e9:.1f} "
            f"| {coll.get('wire_bytes', 0) / 1e9:.2f} "
            f"| {args_mb:.1f} "
            f"| {_gib(am.get('total_per_dev_B', 0))} "
            f"| {am.get('fits_16GiB', '')} |")
    return "\n".join(lines)


def roofline_table(results: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| MODEL_FLOPS (total) | useful ratio | what moves the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        ("memory", True): "XLA-CPU byte inflation (unfused attention); on "
                          "TPU flash-attn + fusion puts this near compute",
        ("memory", False): "HBM-bound: larger per-device batch or better "
                           "fusion",
        ("compute", True): "compute-bound at high useful ratio: healthy",
        ("compute", False): "redundant compute: fix sharding (useful<1)",
        ("collective", True): "collective-bound: overlap or reshard",
        ("collective", False): "collective-bound: overlap or reshard",
    }
    for r in results:
        if r.get("mesh") != mesh or "roofline" not in r:
            continue
        t = r["roofline"]
        u = t.get("useful_flops_ratio")
        dom = t["bottleneck"]
        note = notes.get((dom, (u or 0) > 0.6), "")
        lines.append(
            f"| {_arch_label(r)} | {r['shape']} "
            f"| {_f(t['compute_s'], 4)} | {_f(t['memory_s'], 3)} "
            f"| {_f(t['collective_s'], 4)} | {dom} "
            f"| {t['model_flops_total']:.3g} "
            f"| {_f(u, 3) if u else '—'} | {note} |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    results = json.load(open(path))
    print("## §Dry-run (all cells, both meshes)\n")
    print(dryrun_table(results))
    print("\n## §Roofline (single-pod 16x16)\n")
    print(roofline_table(results, "16x16"))
    print("\n### multi-pod 2x16x16 (shardability proof + scaling check)\n")
    print(roofline_table(results, "2x16x16"))


if __name__ == "__main__":
    main()
