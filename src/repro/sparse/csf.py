"""Compressed Sparse Fiber format, TPU-adapted (paper §2.2).

The classic CSF tree (pointer chasing) is re-laid-out as *flattened
per-level arrays*, which is the TPU-native form: every sparse loop level p
becomes three contiguous int32 arrays

  coord[p]  : (nfib_p,)  the p-th coordinate of each level-p fiber
  parent[p] : (nfib_p,)  index of the enclosing level-(p-1) fiber
  seg[p]    : (nnz,)     level-p fiber id of every nonzero (for segment_sum)

``nfib_p == nnz^(I1..Ip)`` of the paper.  Traversal becomes vectorized
gather/segment-reduce instead of a tree walk; ranges of children are
contiguous because coordinates are lexicographically sorted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.coo import COOTensor


@dataclasses.dataclass
class CSFTensor:
    """Flattened CSF: one entry per level, plus leaf values.

    level arrays are indexed 1..order (level p compresses the first p modes);
    ``fiber_coords[p]`` is the (nfib_p, p) array of unique p-prefixes.
    """

    coo: COOTensor
    coord: dict[int, np.ndarray]     # p -> (nfib_p,) p-th coordinate
    parent: dict[int, np.ndarray]    # p -> (nfib_p,) parent fiber at p-1
    seg: dict[int, np.ndarray]       # p -> (nnz,) fiber id per nonzero
    nfib: dict[int, int]             # p -> nnz^(I1..Ip)

    @property
    def order(self) -> int:
        return self.coo.order

    @property
    def nnz(self) -> int:
        return self.coo.nnz

    @property
    def values(self) -> np.ndarray:
        return self.coo.values

    @property
    def shape(self) -> tuple[int, ...]:
        return self.coo.shape

    def nnz_level(self, p: int) -> int:
        """nnz^(I1..Ip) (paper §2.2); p=0 -> 1 (the root), p=order -> nnz."""
        if p == 0:
            return 1
        return self.nfib[p]

    def nnz_levels(self) -> dict[int, int]:
        return {p: self.nnz_level(p) for p in range(self.order + 1)}

    def fiber_coords(self, p: int) -> np.ndarray:
        """(nfib_p, p) coordinates of each level-p fiber prefix."""
        out = np.empty((self.nfib[p], p), dtype=np.int32)
        f = np.arange(self.nfib[p])
        for lvl in range(p, 0, -1):
            out[:, lvl - 1] = self.coord[lvl][f]
            f = self.parent[lvl][f]
        return out


def build_csf(coo: COOTensor) -> CSFTensor:
    """One-time host-side construction (sparsity is fixed — paper §1)."""
    coords = coo.coords
    nnz, order = coords.shape
    coord: dict[int, np.ndarray] = {}
    parent: dict[int, np.ndarray] = {}
    seg: dict[int, np.ndarray] = {}
    nfib: dict[int, int] = {}
    prev_seg = np.zeros(nnz, dtype=np.int64)  # level-0: single root fiber
    for p in range(1, order + 1):
        # a new level-p fiber starts where the p-prefix changes
        if nnz == 0:
            coord[p] = np.zeros(0, np.int32)
            parent[p] = np.zeros(0, np.int32)
            seg[p] = np.zeros(0, np.int32)
            nfib[p] = 0
            continue
        changed = np.zeros(nnz, dtype=bool)
        changed[0] = True
        changed[1:] = np.any(coords[1:, :p] != coords[:-1, :p], axis=1)
        fib_id = np.cumsum(changed) - 1
        starts = np.flatnonzero(changed)
        coord[p] = coords[starts, p - 1].astype(np.int32)
        parent[p] = prev_seg[starts].astype(np.int32)
        seg[p] = fib_id.astype(np.int32)
        nfib[p] = int(fib_id[-1]) + 1
        prev_seg = fib_id
    return CSFTensor(coo=coo, coord=coord, parent=parent, seg=seg, nfib=nfib)


def level_segments(csf: CSFTensor, child: int, parentlvl: int) -> np.ndarray:
    """Segment ids mapping level-``child`` fibers to level-``parentlvl``
    fibers (child > parentlvl).  parentlvl=0 maps everything to one root."""
    if child == parentlvl:
        raise ValueError("child must be deeper than parent")
    if parentlvl == 0:
        return np.zeros(csf.nfib[child] if child > 0 else 1, dtype=np.int32)
    f = np.arange(csf.nfib[child], dtype=np.int64)
    segs = f
    for lvl in range(child, parentlvl, -1):
        segs = csf.parent[lvl][segs]
    return segs.astype(np.int32)
