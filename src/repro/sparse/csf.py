"""Compressed Sparse Fiber format, TPU-adapted (paper §2.2).

The classic CSF tree (pointer chasing) is re-laid-out as *flattened
per-level arrays*, which is the TPU-native form: every sparse loop level p
becomes three contiguous int32 arrays

  coord[p]  : (nfib_p,)  the p-th coordinate of each level-p fiber
  parent[p] : (nfib_p,)  index of the enclosing level-(p-1) fiber
  seg[p]    : (nnz,)     level-p fiber id of every nonzero (for segment_sum)

``nfib_p == nnz^(I1..Ip)`` of the paper.  Traversal becomes vectorized
gather/segment-reduce instead of a tree walk; ranges of children are
contiguous because coordinates are lexicographically sorted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.coo import COOTensor


@dataclasses.dataclass
class CSFTensor:
    """Flattened CSF: one entry per level, plus leaf values.

    level arrays are indexed 1..order (level p compresses the first p modes);
    ``fiber_coords[p]`` is the (nfib_p, p) array of unique p-prefixes.
    """

    coo: COOTensor
    coord: dict[int, np.ndarray]     # p -> (nfib_p,) p-th coordinate
    parent: dict[int, np.ndarray]    # p -> (nfib_p,) parent fiber at p-1
    seg: dict[int, np.ndarray]       # p -> (nnz,) fiber id per nonzero
    nfib: dict[int, int]             # p -> nnz^(I1..Ip)

    @property
    def order(self) -> int:
        return self.coo.order

    @property
    def nnz(self) -> int:
        return self.coo.nnz

    @property
    def values(self) -> np.ndarray:
        return self.coo.values

    @property
    def shape(self) -> tuple[int, ...]:
        return self.coo.shape

    def nnz_level(self, p: int) -> int:
        """nnz^(I1..Ip) (paper §2.2); p=0 -> 1 (the root), p=order -> nnz."""
        if p == 0:
            return 1
        return self.nfib[p]

    def nnz_levels(self) -> dict[int, int]:
        return {p: self.nnz_level(p) for p in range(self.order + 1)}

    def fiber_coords(self, p: int) -> np.ndarray:
        """(nfib_p, p) coordinates of each level-p fiber prefix."""
        out = np.empty((self.nfib[p], p), dtype=np.int32)
        f = np.arange(self.nfib[p])
        for lvl in range(p, 0, -1):
            out[:, lvl - 1] = self.coord[lvl][f]
            f = self.parent[lvl][f]
        return out


def build_csf(coo: COOTensor) -> CSFTensor:
    """One-time host-side construction (sparsity is fixed — paper §1)."""
    coords = coo.coords
    nnz, order = coords.shape
    coord: dict[int, np.ndarray] = {}
    parent: dict[int, np.ndarray] = {}
    seg: dict[int, np.ndarray] = {}
    nfib: dict[int, int] = {}
    prev_seg = np.zeros(nnz, dtype=np.int64)  # level-0: single root fiber
    for p in range(1, order + 1):
        # a new level-p fiber starts where the p-prefix changes
        if nnz == 0:
            coord[p] = np.zeros(0, np.int32)
            parent[p] = np.zeros(0, np.int32)
            seg[p] = np.zeros(0, np.int32)
            nfib[p] = 0
            continue
        changed = np.zeros(nnz, dtype=bool)
        changed[0] = True
        changed[1:] = np.any(coords[1:, :p] != coords[:-1, :p], axis=1)
        fib_id = np.cumsum(changed) - 1
        starts = np.flatnonzero(changed)
        coord[p] = coords[starts, p - 1].astype(np.int32)
        parent[p] = prev_seg[starts].astype(np.int32)
        seg[p] = fib_id.astype(np.int32)
        nfib[p] = int(fib_id[-1]) + 1
        prev_seg = fib_id
    return CSFTensor(coo=coo, coord=coord, parent=parent, seg=seg, nfib=nfib)


def build_csf_batch(coos: "list[COOTensor] | tuple[COOTensor, ...]"
                    ) -> list[CSFTensor]:
    """Amortized CSF construction for a *request batch* (DESIGN.md §9).

    A serving stream hands over many small same-order patterns per step
    (MoE routing masks, per-user masks); building each CSF separately pays
    the fixed numpy dispatch cost of every level pass B times.  This
    builder concatenates the batch with a leading batch-id column — each
    member is already lexicographically sorted, so the concatenation is
    sorted too and needs no re-sort — runs the per-level prefix-change
    scan ONCE over the whole stream, and splits the global fiber arrays
    back per member.  Results are exactly ``[build_csf(c) for c in coos]``
    (tested element-for-element); only the constant factor changes.
    """
    if not coos:
        return []
    order = coos[0].order
    if any(c.order != order for c in coos):
        raise ValueError("batched CSF construction needs same-order tensors")
    sizes = [c.nnz for c in coos]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    total = int(offsets[-1])
    if total == 0:
        return [build_csf(c) for c in coos]
    # batch-id column in front keeps the concatenation lexicographic and
    # forces a fiber break at every member boundary at every level
    ext = np.empty((total, order + 1), dtype=np.int32)
    ext[:, 0] = np.repeat(np.arange(len(coos), dtype=np.int32), sizes)
    ext[:, 1:] = np.concatenate(
        [c.coords for c in coos if c.nnz], axis=0)
    per = [
        {"coord": {}, "parent": {}, "seg": {}, "nfib": {}}
        for _ in coos]
    # level-0: one root fiber per member, globally numbered by batch id
    prev_seg = ext[:, 0].copy()
    prev_offsets = np.arange(len(coos), dtype=np.int64)
    nnz_member = ext[:, 0]                       # member id per nonzero
    for p in range(1, order + 1):
        changed = np.zeros(total, dtype=bool)
        changed[0] = True
        # prefix includes the batch column, so member boundaries always cut
        changed[1:] = np.any(ext[1:, :p + 1] != ext[:-1, :p + 1], axis=1)
        fib_id = np.cumsum(changed) - 1
        starts = np.flatnonzero(changed)
        fib_member = nnz_member[starts]          # member id per fiber
        fib_offsets = np.searchsorted(starts, offsets[:-1])
        # re-base every global id to its member's range in ONE pass, then
        # split into views — no per-member arithmetic
        coord_all = ext[starts, p].astype(np.int32)
        parent_all = (prev_seg[starts]
                      - prev_offsets[fib_member]).astype(np.int32)
        seg_all = (fib_id - fib_offsets[nnz_member]).astype(np.int32)
        coords = np.split(coord_all, fib_offsets[1:])
        parents = np.split(parent_all, fib_offsets[1:])
        segs = np.split(seg_all, offsets[1:-1])
        for b, d in enumerate(per):
            d["coord"][p] = coords[b]
            d["parent"][p] = parents[b]
            d["seg"][p] = segs[b]
            d["nfib"][p] = len(coords[b])
        prev_seg = fib_id
        prev_offsets = fib_offsets.astype(np.int64)
    out = []
    for b, c in enumerate(coos):
        if c.nnz == 0:
            out.append(build_csf(c))  # empty arrays, canonical layout
            continue
        d = per[b]
        out.append(CSFTensor(coo=c, coord=d["coord"], parent=d["parent"],
                             seg=d["seg"], nfib=d["nfib"]))
    return out


def level_segments(csf: CSFTensor, child: int, parentlvl: int) -> np.ndarray:
    """Segment ids mapping level-``child`` fibers to level-``parentlvl``
    fibers (child > parentlvl).  parentlvl=0 maps everything to one root."""
    if child == parentlvl:
        raise ValueError("child must be deeper than parent")
    if parentlvl == 0:
        return np.zeros(csf.nfib[child] if child > 0 else 1, dtype=np.int32)
    f = np.arange(csf.nfib[child], dtype=np.int64)
    segs = f
    for lvl in range(child, parentlvl, -1):
        segs = csf.parent[lvl][segs]
    return segs.astype(np.int32)
