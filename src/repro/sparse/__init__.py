from repro.sparse.coo import COOTensor, random_sparse, from_dense
from repro.sparse.csf import CSFTensor, build_csf

__all__ = ["COOTensor", "random_sparse", "from_dense", "CSFTensor",
           "build_csf"]
