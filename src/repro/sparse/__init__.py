from repro.sparse.coo import COOTensor, random_sparse, from_dense
from repro.sparse.csf import CSFTensor, build_csf, build_csf_batch

__all__ = ["COOTensor", "random_sparse", "from_dense", "CSFTensor",
           "build_csf", "build_csf_batch"]
