from repro.sparse.coo import COOTensor, from_dense, random_sparse
from repro.sparse.csf import CSFTensor, build_csf, build_csf_batch

__all__ = ["COOTensor", "random_sparse", "from_dense", "CSFTensor",
           "build_csf", "build_csf_batch"]
