"""COO sparse tensors (host-side construction; fixed sparsity pattern).

The paper's key structural assumption is that SpTTN kernels have a single
fixed, data-independent sparsity pattern, so all format construction happens
once on the host (numpy) and the resulting index arrays are reused across
every contraction (and every optimizer step).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class COOTensor:
    """Coordinates are lexicographically sorted and duplicate-free."""

    coords: np.ndarray  # (nnz, order) int32
    values: np.ndarray  # (nnz,)
    shape: tuple[int, ...]

    @property
    def order(self) -> int:
        return len(self.shape)

    @property
    def nnz(self) -> int:
        return self.coords.shape[0]

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.values.dtype)
        out[tuple(self.coords.T)] = self.values
        return out

    def permute_modes(self, perm: tuple[int, ...]) -> "COOTensor":
        coords = self.coords[:, list(perm)]
        shape = tuple(self.shape[p] for p in perm)
        return _sorted(coords, self.values.copy(), shape)


def _sorted(coords: np.ndarray, values: np.ndarray,
            shape: tuple[int, ...]) -> COOTensor:
    key = np.lexsort(coords.T[::-1])
    return COOTensor(coords=np.ascontiguousarray(coords[key]),
                     values=np.ascontiguousarray(values[key]), shape=shape)


def from_dense(a: np.ndarray) -> COOTensor:
    coords = np.argwhere(a != 0).astype(np.int32)
    values = a[tuple(coords.T)]
    return _sorted(coords, values, a.shape)


def from_coords(coords: np.ndarray, values: np.ndarray,
                shape: tuple[int, ...], sum_duplicates: bool = True
                ) -> COOTensor:
    coords = np.asarray(coords, dtype=np.int32)
    values = np.asarray(values)
    t = _sorted(coords, values, shape)
    if sum_duplicates and t.nnz > 1:
        same = np.all(t.coords[1:] == t.coords[:-1], axis=1)
        if same.any():
            keep = np.concatenate([[True], ~same])
            seg = np.cumsum(keep) - 1
            vals = np.zeros(int(seg[-1]) + 1, dtype=t.values.dtype)
            np.add.at(vals, seg, t.values)
            t = COOTensor(coords=t.coords[keep], values=vals, shape=shape)
    return t


def random_sparse(shape: tuple[int, ...], density: float,
                  seed: int = 0, dtype=np.float32,
                  distribution: str = "uniform") -> COOTensor:
    """Random sparse tensor with ~density fraction of nonzeros.

    ``distribution='frostt'`` skews nonzeros toward a power-law fiber-length
    profile resembling real FROSTT tensors (nell-2 etc.); 'uniform' samples
    coordinates i.i.d.
    """
    rng = np.random.default_rng(seed)
    total = int(np.prod([float(s) for s in shape]))
    nnz = max(1, int(round(total * density)))
    nnz = min(nnz, total)
    if distribution == "frostt" and len(shape) >= 2:
        # power-law weights over the leading mode => skewed slice sizes
        w = 1.0 / np.arange(1, shape[0] + 1) ** 0.8
        w /= w.sum()
        lead = rng.choice(shape[0], size=2 * nnz, p=w)
        rest = [rng.integers(0, s, size=2 * nnz) for s in shape[1:]]
        coords = np.stack([lead, *rest], axis=1).astype(np.int32)
    else:
        coords = np.stack([rng.integers(0, s, size=2 * nnz) for s in shape],
                          axis=1).astype(np.int32)
    coords = np.unique(coords, axis=0)[:nnz]
    values = rng.standard_normal(coords.shape[0]).astype(dtype)
    return _sorted(coords, values, tuple(shape))


def long_fiber_sparse(shape: tuple[int, int, int], n_fibers: int,
                      fiber_len: int, seed: int = 0,
                      dtype=np.float32) -> COOTensor:
    """Sparse tensor with ~fiber_len nonzeros per (i,j) fiber — the regime
    where factorize-and-fuse asymptotically beats unfactorized (paper
    §2.4.2: 2·nnz·R + 2·nnz^(IJ)·R  vs  3·nnz·R requires nnz >> nnz^(IJ)).
    Real decomposition datasets (nell-2 et al.) are of this kind."""
    rng = np.random.default_rng(seed)
    ij = np.stack([rng.integers(0, shape[0], n_fibers),
                   rng.integers(0, shape[1], n_fibers)], axis=1)
    ij = np.unique(ij, axis=0)
    ks = rng.integers(0, shape[2], size=(len(ij), fiber_len))
    coords = np.concatenate(
        [np.repeat(ij, fiber_len, axis=0),
         ks.reshape(-1, 1)], axis=1).astype(np.int32)
    coords = np.unique(coords, axis=0)
    values = rng.standard_normal(len(coords)).astype(dtype)
    return _sorted(coords, values, shape)


def banded_mask(n: int, window: int, block: int = 1) -> COOTensor:
    """Causal banded (sliding-window) mask pattern as a sparse tensor —
    the static sparsity of local attention (gemma3/recurrentgemma), at
    ``block`` granularity for the block-sparse SDDMM kernel."""
    nb = (n + block - 1) // block
    wb = max(1, (window + block - 1) // block)
    rows, cols = [], []
    for i in range(nb):
        j0 = max(0, i - wb + 1)
        for j in range(j0, i + 1):
            rows.append(i)
            cols.append(j)
    coords = np.stack([np.array(rows), np.array(cols)], axis=1).astype(np.int32)
    values = np.ones(len(rows), dtype=np.float32)
    return _sorted(coords, values, (nb, nb))
