"""Distributed-optimization primitives: gradient compression + overlap.

``compressed_psum`` — int8 stochastic-rounding all-reduce: blockwise scale,
quantize, psum int32, dequantize.  Unbiased (E[deq] = x); cuts gradient
all-reduce bytes 4x vs fp32 (2x vs bf16).  Off by default; enabled per
RunConfig for bandwidth-bound meshes.

``reduce_scatter_grads`` — psum_scatter along the FSDP axis so each shard
only materializes its own gradient slice (ZeRO-2 shape), letting XLA's
latency-hiding scheduler overlap the scatter with backprop compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map.

    Newer jax exposes ``jax.shard_map(..., check_vma=)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
    The two flags gate the same replication/varying-manual-axes check.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def _quantize_block(x, key, block: int = 256):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = blocks / scale
    noise = jax.random.uniform(key, scaled.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale, x.shape, pad


def _dequantize_block(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(x, axis_name: str, key, block: int = 256):
    """Unbiased int8 stochastic-rounding all-reduce over ``axis_name``.

    int8 payloads + per-block fp32 scales are all-gathered and the exact
    dequantized sum is formed locally — 1/4 the wire bytes of an fp32 ring
    all-reduce (scales add 4/block overhead).  Stochastic rounding keeps
    E[result] equal to the uncompressed psum; variance is O(scale^2/12) per
    element (tested for unbiasedness in tests/test_collectives.py).
    """
    q, scale, shape, pad = _quantize_block(x, key, block)
    qg = jax.lax.all_gather(q, axis_name)            # (P, nblk, block) int8
    sg = jax.lax.all_gather(scale, axis_name)        # (P, nblk, 1) fp32
    total = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    return _dequantize_block(total, jnp.ones_like(scale), shape, pad)


def reduce_scatter_grads(grads, axis_name: str, tiled_axis: int = 0):
    """psum_scatter every leaf along ``axis_name`` (ZeRO-2 gradient shape).
    Leaves whose dim 0 does not divide the axis size are psum'd whole."""
    if hasattr(jax.lax, "axis_size"):
        size = jax.lax.axis_size(axis_name)
    else:  # older jax: psum of a unit constant folds to the axis size
        size = int(jax.lax.psum(1, axis_name))

    def one(g):
        if g.ndim and g.shape[0] % size == 0 and g.shape[0] >= size:
            return jax.lax.psum_scatter(g, axis_name, scatter_dimension=0,
                                        tiled=True)
        return jax.lax.psum(g, axis_name)

    return jax.tree.map(one, grads)
