"""Distributed SpTTN execution — the paper's §5.2 mapped to shard_map.

CTF layout, TPU-native:
  * the sparse tensor is partitioned by tensor modes onto mesh axes and
    NEVER moves (cyclic load balance = host-side row permutation + block
    partition, which is the same layout up to relabeling);
  * each dense factor is sharded along the modes it shares with a
    partitioned sparse mode and *partially replicated* along every other
    mesh axis (the paper's replication scheme);
  * each device runs the SAME fused loop-nest plan on its local CSF (the
    local problem is an SpTTN of identical structure — paper §1);
  * the output is reduced (psum) only over mesh axes that own contracted
    sparse modes, and comes out naturally sharded over output modes.

Local CSFs are padded to common sizes so one jaxpr serves all shards; all
padding is provably zero-contributing (zero values, segment tails held at
the last segment id so every segment map stays sorted).

Three execution modes (DESIGN.md §7, docs/distributed.md):

* :func:`make_distributed` — the XLA collective engine: one plan, one
  shard_map jaxpr, psum over contracted partitioned modes.
* :func:`make_distributed_pallas` — the stacked Pallas engine: the
  generated-kernel executor traced ONCE inside shard_map for every
  shard.  Pallas stages need concrete segment layouts at trace time, so
  each shard's block layout is precomputed on host, padded to the
  mesh-wide maximum with inert blocks, stacked ``(n_shards, ...)``, and
  re-installed per shard inside the traced function — the scalar-
  prefetch operands become traced per-shard slices, the kernel trace is
  shared, and contracted-mode partials still reduce with psum (no host
  round trip).
* :func:`make_distributed_tuned` — distributed *plan replay*: the
  autotuner runs (or cache-hits) per shard on each shard's local nnz
  profile.  Homogeneous XLA winners route through the collective
  engine; homogeneous Pallas winners whose plan passes
  :func:`stackable_plan` route through the stacked Pallas engine
  (mode ``"collective-pallas"``); anything else replays shard-by-shard
  with a host-side sum of partials (exact, since shards keep global
  coordinates and partition the nonzeros).
"""
from __future__ import annotations

import dataclasses
import types
from collections.abc import Mapping

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.diagnostics import PALLAS_BACKENDS
from repro.analysis.invariants import plan_layout_walk as _plan_layout_walk
from repro.core.executor import CSFArrays, VectorizedExecutor
from repro.core.planner import SpTTNPlan
from repro.core.spec import SpTTNSpec
from repro.sparse.coo import COOTensor
from repro.sparse.csf import build_csf, level_segments


@dataclasses.dataclass
class DistributedSpTTN:
    """Compiled distributed kernel: call with (values_stack, factors)."""

    spec: SpTTNSpec
    plan: SpTTNPlan
    mesh: Mesh
    mode_axis: dict[int, str]           # sparse mode -> mesh axis
    stacked: dict                       # (P, ...) padded CSF arrays
    perm: np.ndarray                    # nnz permutation (global -> stacked)
    fn: object                          # jitted shard_map callable
    factor_perm: dict = dataclasses.field(default_factory=dict)

    def __call__(self, factors: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        prepared = {}
        for name, arr in factors.items():
            perm = self.factor_perm.get(name)
            if perm is not None:
                axis, take = perm
                arr = jnp.asarray(arr)
                pad = [(0, 0)] * arr.ndim
                pad[axis] = (0, 1)  # zero row for out-of-range slots
                arr = jnp.pad(arr, pad)
                arr = jnp.take(arr, jnp.asarray(take), axis=axis)
            prepared[name] = arr
        return self.fn(self.stacked, prepared)


def _pad_local_csf(csf, max_nnz: int, max_nfib: dict[int, int]):
    """Flattened per-level arrays padded with zero-contribution entries.

    Values pad with zeros and fiber coordinates with 0 (a real local
    coordinate — harmless because the padded values are zero).  Segment
    tails pad with the LAST segment id (``max_nfib[par] - 1``), not 0:
    every CSF segment map is sorted ascending, and both the Pallas block
    layouts (:func:`repro.kernels.util.padded_segment_layout`) and
    ``segment_sum(..., indices_are_sorted=True)`` rely on that — a zero
    tail after a nonzero id would silently break it.  The padded rows
    still contribute nothing (their values are zero), they just
    accumulate into the final row instead of row 0.
    """
    order = csf.order
    out = {"values": np.zeros(max_nnz, csf.values.dtype)}
    out["values"][: csf.nnz] = csf.values
    for p in range(1, order + 1):
        fc = csf.fiber_coords(p)
        for m in range(p):
            a = np.zeros(max_nfib[p], np.int32)
            a[: csf.nfib[p]] = fc[:, m]
            out[f"coord_{p}_{m}"] = a
    for child in range(1, order + 1):
        for par in range(0, child):
            seg = level_segments(csf, child, par)
            padval = (max_nfib[par] - 1) if par > 0 else 0
            a = np.full(max_nfib[child], padval, np.int32)
            a[: len(seg)] = seg
            out[f"seg_{child}_{par}"] = a
    return out


def unpad_local_csf(packed: Mapping[str, np.ndarray], order: int,
                    nnz: int, nfib: Mapping[int, int]) -> dict:
    """Invert :func:`_pad_local_csf`: slice one shard's padded arrays
    back to its real ``nnz`` / per-level ``nfib`` counts.  Padding never
    mixes into real slots (it is strictly appended), so the round trip
    is bit-exact — the property the stacked engines rest on, and what
    the hypothesis suite in tests/test_stacked_dist.py checks."""
    out = {"values": np.asarray(packed["values"])[:nnz]}
    for p in range(1, order + 1):
        for m in range(p):
            out[f"coord_{p}_{m}"] = \
                np.asarray(packed[f"coord_{p}_{m}"])[: nfib[p]]
    for child in range(1, order + 1):
        for par in range(0, child):
            out[f"seg_{child}_{par}"] = \
                np.asarray(packed[f"seg_{child}_{par}"])[: nfib[child]]
    return out


def _unpack_csf(stacked_local: dict, order: int, nfib: dict[int, int],
                shape) -> CSFArrays:
    fiber_coord = {p: {m: stacked_local[f"coord_{p}_{m}"]
                       for m in range(p)} for p in range(1, order + 1)}
    seg = {(c, par): stacked_local[f"seg_{c}_{par}"]
           for c in range(1, order + 1) for par in range(0, c)}
    return CSFArrays(values=stacked_local["values"], fiber_coord=fiber_coord,
                     seg=seg, nfib=nfib, order=order, shape=shape)


@dataclasses.dataclass
class MeshPartition:
    """Host-side result of partitioning a COO over the mesh — everything
    the shard_map engines share: per-shard padded CSF arrays (numpy
    ``packed`` for layout precomputation, jnp ``stacked`` for the traced
    call), factor/output shardings, and the psum axes.  Built by
    :func:`partition_mesh`; consumed by :func:`make_distributed` (XLA
    collective) and :func:`make_distributed_pallas` (stacked Pallas)."""

    order: int
    nshards: int
    csfs: list                          # per-shard local CSFTensors
    packed: list                        # per-shard padded numpy arrays
    stacked: dict                       # (n_shards, ...) jnp arrays
    perm: np.ndarray                    # nnz permutation (global -> stacked)
    local_shape: tuple
    local_spec: SpTTNSpec
    max_nnz: int
    max_nfib: dict
    part_axes: tuple
    factor_specs: dict
    factor_perm: dict
    out_spec: object
    reduce_axes: list


def partition_mesh(spec: SpTTNSpec, coo: COOTensor, mesh: Mesh,
                   mode_axis: dict[int, str],
                   cyclic: bool = True) -> MeshPartition:
    """Partition ``coo`` per ``mode_axis`` into the stacked shard layout.

    Only mode 0 (+ optionally mode 1) partitioning is exercised in tests;
    the construction is generic over any subset of modes.
    """
    sp_inds = spec.sparse_indices
    shape = coo.shape
    coords = coo.coords.copy()
    values = coo.values.copy()

    # cyclic load balance == row permutation + block partition
    nparts = {m: mesh.shape[ax] for m, ax in mode_axis.items()}
    local_dim = {m: -(-shape[m] // nparts[m]) for m in mode_axis}
    owner = np.zeros(len(values), np.int64)
    mult = 1
    part_of = {}
    for m, ax in mode_axis.items():
        if cyclic:
            part = coords[:, m] % nparts[m]
            local = coords[:, m] // nparts[m]
        else:
            part = coords[:, m] // local_dim[m]
            local = coords[:, m] % local_dim[m]
        coords[:, m] = local
        part_of[m] = part
        owner = owner * nparts[m] + part
        mult *= nparts[m]
    nshards = mult

    # bucket nonzeros per shard, build local CSFs, pad to common sizes
    order = coo.order
    buckets = [np.flatnonzero(owner == s) for s in range(nshards)]
    local_shape = tuple(local_dim.get(m, shape[m]) for m in range(order))
    csfs = []
    sorted_ids = []                 # global nnz id per (shard, local slot)
    for idx in buckets:
        key = np.lexsort(coords[idx].T[::-1])
        lc = COOTensor(coords=np.ascontiguousarray(coords[idx][key]),
                       values=np.ascontiguousarray(values[idx][key]),
                       shape=local_shape)
        csfs.append(build_csf(lc))
        sorted_ids.append(idx[key])
    max_nnz = max(max(c.nnz for c in csfs), 1)
    max_nfib = {p: max(max(c.nfib.get(p, 0) for c in csfs), 1)
                for p in range(1, order + 1)}
    packed = [_pad_local_csf(c, max_nnz, max_nfib) for c in csfs]
    stacked = {k: jnp.asarray(np.stack([pk[k] for pk in packed]))
               for k in packed[0]}

    # shardings: stacked CSF arrays over the partition axes (flattened)
    part_axes = tuple(mode_axis[m] for m in mode_axis)
    dims_local = dict(spec.dims)
    for m, ind in enumerate(sp_inds):
        if m in mode_axis:
            dims_local[ind] = local_shape[m]
    import dataclasses as dc
    local_spec = dc.replace(
        spec,
        dims=dims_local,
        output=spec.output)

    # factor shardings: shard along partitioned shared modes, replicate
    # rest (paper §5.2 partial replication).  shard_map splits factor rows
    # BLOCK-wise, so rows are pre-permuted into [part, local] stacked order
    # to match the (cyclic) relabeling of the sparse tensor's coordinates.
    factor_specs = {}
    factor_perm: dict[str, tuple[int, np.ndarray] | None] = {}
    for t in spec.inputs:
        if t.is_sparse:
            continue
        parts = []
        factor_perm[t.name] = None
        for axpos, ind in enumerate(t.indices):
            ax = None
            for m, a in mode_axis.items():
                if sp_inds[m] == ind:
                    ax = a
                    P_m, Ld, I_m = nparts[m], local_dim[m], shape[m]
                    take = np.full(P_m * Ld, I_m, np.int64)  # pad row id
                    for part in range(P_m):
                        for l in range(Ld):
                            g = (l * P_m + part) if cyclic else \
                                (part * Ld + l)
                            if g < I_m:
                                take[part * Ld + l] = g
                    factor_perm[t.name] = (axpos, take)
            parts.append(ax)
        factor_specs[t.name] = P(*parts)

    # output sharding: partitioned output sparse modes stay sharded;
    # contracted partitioned modes need a psum
    out_parts = []
    reduce_axes = []
    for ind in spec.output.indices:
        ax = None
        for m, a in mode_axis.items():
            if sp_inds[m] == ind:
                ax = a
        out_parts.append(ax)
    for m, a in mode_axis.items():
        if sp_inds[m] not in spec.output.indices:
            reduce_axes.append(a)
    out_spec = P(*out_parts) if not spec.output_is_sparse else P(part_axes)

    return MeshPartition(
        order=order, nshards=nshards, csfs=csfs, packed=packed,
        stacked=stacked, perm=np.concatenate(sorted_ids),
        local_shape=local_shape, local_spec=local_spec, max_nnz=max_nnz,
        max_nfib=max_nfib, part_axes=part_axes, factor_specs=factor_specs,
        factor_perm=factor_perm, out_spec=out_spec,
        reduce_axes=reduce_axes)


def _compile_shard_map(mesh: Mesh, part: MeshPartition, local_fn,
                       extra_stacked: dict | None = None):
    """jit(shard_map(local_fn)) over the stacked arrays (+ any stacked
    layout tables), every stacked input sharded over the partition axes."""
    stacked = dict(part.stacked)
    if extra_stacked:
        stacked.update(extra_stacked)
    csf_specs = {k: P(part.part_axes) for k in stacked}
    from repro.distributed.collectives import shard_map
    fn = jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=(csf_specs, part.factor_specs),
        out_specs=part.out_spec,
        check_vma=False))
    return stacked, fn


def make_distributed(spec: SpTTNSpec, plan: SpTTNPlan, coo: COOTensor,
                     mesh: Mesh, mode_axis: dict[int, str],
                     cyclic: bool = True) -> DistributedSpTTN:
    """Partition ``coo`` per ``mode_axis`` and build the XLA collective
    shard_map kernel (one :class:`VectorizedExecutor` jaxpr serves every
    shard; see :func:`make_distributed_pallas` for the generated-kernel
    sibling)."""
    part = partition_mesh(spec, coo, mesh, mode_axis, cyclic=cyclic)
    executor = VectorizedExecutor(part.local_spec, plan.path, plan.order)
    nfib_static = dict(part.max_nfib)
    order, local_shape = part.order, part.local_shape
    reduce_axes = part.reduce_axes

    def local_fn(stacked_local, factors):
        # shard_map delivers block-local arrays with a leading shard dim of 1
        local = {k: v.reshape(v.shape[1:]) for k, v in stacked_local.items()}
        arrays = _unpack_csf(local, order, nfib_static, local_shape)
        out = executor(arrays, factors)
        for a in reduce_axes:
            out = jax.lax.psum(out, a)
        return out

    stacked, fn = _compile_shard_map(mesh, part, local_fn)
    dist = DistributedSpTTN(spec=spec, plan=plan, mesh=mesh,
                            mode_axis=dict(mode_axis), stacked=stacked,
                            perm=part.perm, fn=fn,
                            factor_perm=part.factor_perm)
    dist.nnz_per_shard = [c.nnz for c in part.csfs]
    dist.max_nnz = part.max_nnz
    return dist


# =========================================================================== #
# Stacked-layout Pallas engine: one generated-kernel trace for all shards
# =========================================================================== #
# The zero-on-pads induction walk is a static invariant, owned by the
# verifier (repro.analysis.invariants.plan_layout_walk, imported above
# as ``_plan_layout_walk``): the stacked lowering consumes the walk's
# layout *requests*, the verifier its stackability verdict.
def stackable_plan(spec: SpTTNSpec, path, fused: bool = False) -> bool:
    """True when a plan can run through the stacked Pallas engine.

    Structural check, no CSF needed: every sparse-structured stage must
    consume at least one operand that is zero on padded fibers at the
    stage's own CSF level (the sparse leaf values, or an intermediate
    produced by such a stage).  Pad fibers then multiply to zero
    everywhere and the zero-nnz tails of the stacked layout contribute
    nothing on any shard — including entirely empty shard slots.  Dense
    outputs only; :func:`make_distributed_tuned` falls back to replay
    when this returns False.

    Thin wrapper over
    :func:`repro.analysis.invariants.stackable_diagnostics` — the
    verifier's E051/E052 diagnostics ARE this predicate, so engine
    routing and static verification cannot disagree."""
    from repro.analysis.invariants import stackable_diagnostics
    return not stackable_diagnostics(spec, path, fused=fused)


def _stacked_layout_tables(part: MeshPartition, ex, requests):
    """Precompute every shard's Pallas block layouts, pad them to the
    mesh-wide maximum with inert blocks, and stack to ``(n_shards, ...)``
    tables that ride into shard_map next to the CSF arrays.

    Returns ``(extra_stacked, manifest)`` — the jnp tables plus the
    recipe :func:`_install_stacked_layouts` uses to rebuild each shard's
    layout-cache entries from traced local slices."""
    from repro.kernels.codegen.executor import (chain_block_arrays,
                                                chain_layout_key,
                                                stage_layout_key)
    from repro.kernels.util import (pad_segment_layout,
                                    padded_segment_layout)

    shard_views = []
    for pk in part.packed:
        seg = {(c, par): pk[f"seg_{c}_{par}"]
               for c in range(1, part.order + 1) for par in range(0, c)}
        shard_views.append(types.SimpleNamespace(seg=seg,
                                                 nfib=dict(part.max_nfib)))

    extra: dict[str, np.ndarray] = {}
    manifest: list[tuple] = []
    for req in requests:
        if req[0] == "stage":
            _, lvl, out_lvl = req
            nseg = part.max_nfib[out_lvl] if out_lvl > 0 else 1
            lays = [padded_segment_layout(v.seg[(lvl, out_lvl)], nseg,
                                          ex.block) for v in shard_views]
            pmax = max(l.padded_len for l in lays)
            lays = [pad_segment_layout(l, pmax) for l in lays]
            name = f"stage_{lvl}_{out_lvl}"
            extra[f"{name}__gather"] = np.stack([l.gather for l in lays])
            extra[f"{name}__mask"] = np.stack([l.mask for l in lays])
            extra[f"{name}__bseg"] = np.stack([l.block_seg for l in lays])
            extra[f"{name}__bfirst"] = np.stack([l.block_first
                                                 for l in lays])
            manifest.append(("stage", stage_layout_key(lvl, out_lvl,
                                                       ex.block),
                             name, nseg, 0))
        else:
            _, lvl0, levels = req
            per = [chain_block_arrays(v, lvl0, levels, ex.block)
                   for v in shard_views]
            pmax = max(p[0].padded_len for p in per)
            nbmax = pmax // ex.block
            name = "chain_" + "_".join(map(str, (lvl0,) + levels))
            gathers, masks = [], []
            segs_j = [[] for _ in levels]
            firsts_j = [[] for _ in levels]
            lasts_j = [[] for _ in levels]
            for lay, segs, firsts, lasts in per:
                lay = pad_segment_layout(lay, pmax)
                gathers.append(lay.gather)
                masks.append(lay.mask)
                for j in range(len(levels)):
                    nb = nbmax - segs[j].shape[0]
                    # inert appended blocks: edge segment id (contiguous
                    # revisit of the final row), never first, never last
                    # (no buffer reset, no flush)
                    segs_j[j].append(np.pad(segs[j], (0, nb), mode="edge"))
                    firsts_j[j].append(np.pad(firsts[j], (0, nb)))
                    lasts_j[j].append(np.pad(lasts[j], (0, nb)))
            extra[f"{name}__gather"] = np.stack(gathers)
            extra[f"{name}__mask"] = np.stack(masks)
            for j in range(len(levels)):
                extra[f"{name}__seg{j}"] = np.stack(segs_j[j])
                extra[f"{name}__first{j}"] = np.stack(firsts_j[j])
                if j < len(levels) - 1:   # outermost flush is the grid end
                    extra[f"{name}__last{j}"] = np.stack(lasts_j[j])
            manifest.append(("chain", chain_layout_key(lvl0, levels,
                                                       ex.block),
                             name, part.max_nfib[levels[0]], len(levels)))
    return {k: jnp.asarray(v) for k, v in extra.items()}, manifest


def _install_stacked_layouts(arrays: CSFArrays, local: Mapping,
                             manifest, block: int) -> None:
    """Populate the executor's layout cache with this shard's traced
    slices so the Pallas lowering never touches numpy at trace time.
    The ``lay`` slot becomes a static stub carrying only ``nseg`` —
    the one attribute the lowering reads from it."""
    from repro.kernels.codegen.executor import (chain_cache_entry,
                                                layout_cache,
                                                stage_cache_entry)
    from repro.kernels.util import PaddedSegments

    cache = layout_cache(arrays)
    empty_i = np.zeros(0, np.int32)
    for kind, key, name, nseg, nlvl in manifest:
        stub = PaddedSegments(gather=empty_i, mask=np.zeros(0, np.float32),
                              block_seg=empty_i, block_first=empty_i,
                              nseg=nseg, block=block)
        if kind == "stage":
            cache[key] = stage_cache_entry(
                stub, local[f"{name}__gather"], local[f"{name}__mask"],
                local[f"{name}__bseg"], local[f"{name}__bfirst"])
        else:
            cache[key] = chain_cache_entry(
                stub, local[f"{name}__gather"], local[f"{name}__mask"],
                tuple(local[f"{name}__seg{j}"] for j in range(nlvl)),
                tuple(local[f"{name}__first{j}"] for j in range(nlvl)),
                tuple(local[f"{name}__last{j}"] for j in range(nlvl - 1)))


def make_distributed_pallas(spec: SpTTNSpec, plan: SpTTNPlan,
                            coo: COOTensor, mesh: Mesh,
                            mode_axis: dict[int, str], cyclic: bool = True,
                            **executor_kwargs) -> DistributedSpTTN:
    """The stacked Pallas engine: ONE generated-kernel trace inside
    shard_map serves every shard, contracted-mode partials reduce with
    psum — no host round trip, no per-shard retrace.

    Pallas stages need concrete block-segment layouts at trace time,
    which per-shard tracers cannot provide; instead each shard's layout
    is precomputed on host from its padded CSF, padded to the mesh-wide
    maximum with inert blocks, stacked, and passed through shard_map as
    extra sharded inputs.  Inside the traced function the local slices
    are re-installed into the executor's layout cache, turning the
    scalar-prefetch operands into traced per-shard values under one
    shared kernel trace.

    ``plan`` must be homogeneous across shards (one schedule for all)
    and pass :func:`stackable_plan`; extra kwargs reach
    :class:`~repro.kernels.codegen.PallasPlanExecutor` (``block``,
    ``strategy``, ``tile_align``, ``interpret``) — ``plan.fused`` and
    ``plan.block`` are applied automatically like plan replay does.
    """
    if spec.output_is_sparse:
        raise ValueError(
            "make_distributed_pallas requires a dense output; same-"
            "sparsity (TTTP-like) outputs go through make_distributed")
    from repro.kernels.codegen import PallasPlanExecutor

    part = partition_mesh(spec, coo, mesh, mode_axis, cyclic=cyclic)
    kw = dict(executor_kwargs)
    if plan.fused:
        kw.setdefault("strategy", "fused")
    if getattr(plan, "block", None):
        kw.setdefault("block", plan.block)
    ex = PallasPlanExecutor(part.local_spec, plan.path, plan.order, **kw)

    nfib_stub = types.SimpleNamespace(nfib=dict(part.max_nfib))
    ok, requests = _plan_layout_walk(
        spec, plan.path, ex._chains,
        lambda lvl, out_lvl: ex.strategy_for(nfib_stub, lvl,
                                             out_lvl) == "row")
    if not ok:
        raise ValueError(
            "plan is not stackable: some sparse-structured stage has no "
            "operand that is zero on padded fibers at its own CSF level, "
            "so the stacked zero-nnz tails would pollute the result — "
            "check stackable_plan() first and fall back to replay "
            "[SPTTN-E051]")
    extra, manifest = _stacked_layout_tables(part, ex, requests)

    nfib_static = dict(part.max_nfib)
    order, local_shape = part.order, part.local_shape
    reduce_axes = part.reduce_axes
    block = ex.block

    def local_fn(stacked_local, factors):
        local = {k: v.reshape(v.shape[1:]) for k, v in stacked_local.items()}
        arrays = _unpack_csf(local, order, nfib_static, local_shape)
        _install_stacked_layouts(arrays, local, manifest, block)
        out = ex(arrays, factors)
        for a in reduce_axes:
            out = jax.lax.psum(out, a)
        return out

    stacked, fn = _compile_shard_map(mesh, part, local_fn, extra)
    dist = DistributedSpTTN(spec=spec, plan=plan, mesh=mesh,
                            mode_axis=dict(mode_axis), stacked=stacked,
                            perm=part.perm, fn=fn,
                            factor_perm=part.factor_perm)
    dist.nnz_per_shard = [c.nnz for c in part.csfs]
    dist.max_nnz = part.max_nnz
    dist.executor = ex           # inspection: emitted stages / strategies
    dist.layout_manifest = manifest
    return dist


# =========================================================================== #
# Distributed plan replay (DESIGN.md §7): per-shard tuned backends
# =========================================================================== #
def shard_mesh_key(mesh, mode_axis: Mapping[int, str],
                   shard: int) -> dict:
    """JSON-able shard context for the plan cache key (DESIGN.md §7).

    Names everything that distinguishes one shard-local tuning problem
    from the single-device one and from other mesh layouts: the sizes of
    the partitioned mesh axes, the mode→axis assignment, and the shard
    index.  Feed it to ``TunerConfig.mesh`` /
    :func:`repro.autotune.cache_key`; it is also stamped onto the tuned
    plan and persisted in plan JSON v3.

    ``mesh`` is a :class:`jax.sharding.Mesh` or a plain ``{axis: size}``
    mapping (handy for key computations without building devices).

    >>> shard_mesh_key({"data": 4}, {0: "data"}, shard=2)
    {'mesh_shape': {'data': 4}, 'mode_axis': {'0': 'data'}, 'shard': 2}
    """
    shape = mesh.shape if hasattr(mesh, "shape") else mesh
    return {
        "mesh_shape": {ax: int(shape[ax])
                       for ax in sorted(set(mode_axis.values()))},
        "mode_axis": {str(m): ax for m, ax in sorted(mode_axis.items())},
        "shard": int(shard),
    }


def partition_nonzeros(coo: COOTensor, nparts: Mapping[int, int],
                       cyclic: bool = True) -> list[COOTensor]:
    """Partition ``coo``'s nonzeros by (cyclic) ownership over the
    partitioned modes, **keeping global coordinates** — each shard is a
    same-shape COO holding a disjoint nonzero subset, so per-shard dense
    partial outputs sum exactly to the global output (the replay-mode
    reduction; contrast :func:`make_distributed`, which relabels
    coordinates for the equal-block shard_map layout).

    ``nparts`` maps mode → number of parts; ownership composes over modes
    in sorted order (mixed radix, same shard enumeration as
    :func:`make_distributed`'s owner computation for one-mode grids).
    """
    owner = np.zeros(coo.nnz, np.int64)
    nshards = 1
    for m in sorted(nparts):
        P_m = int(nparts[m])
        if cyclic:
            part = coo.coords[:, m] % P_m
        else:
            local_dim = -(-coo.shape[m] // P_m)
            part = coo.coords[:, m] // local_dim
        owner = owner * P_m + part
        nshards *= P_m
    out = []
    for s in range(nshards):
        idx = np.flatnonzero(owner == s)
        # a subset of lexicographically sorted rows stays sorted
        out.append(COOTensor(coords=np.ascontiguousarray(coo.coords[idx]),
                             values=np.ascontiguousarray(coo.values[idx]),
                             shape=coo.shape))
    return out


@dataclasses.dataclass
class TunedShard:
    """One shard of a :class:`DistributedPlanReplay`: the shard-locally
    tuned plan, the search stats (cache hit/miss accounting), and the
    compiled executor closure.  Only the operand representation the
    shard's backend executes is retained — ``csf`` (host CSFTensor,
    global coordinates) for ``reference`` replay, ``arrays`` for
    ``xla``/``pallas`` replay, neither in collective mode (the shard_map
    engine builds its own stacked layout)."""

    index: int
    nnz: int
    plan: SpTTNPlan | None       # None for an empty shard
    stats: object | None         # autotune SearchStats
    csf: object | None = None
    arrays: CSFArrays | None = None
    fn: object | None = None     # factors -> partial output


#: the three distributed execution modes a tuned replay can land on
DIST_MODES = ("collective", "collective-pallas", "replay")


@dataclasses.dataclass
class DistributedPlanReplay:
    """Distributed SpTTN execution with per-shard tuned plans.

    ``mode`` is one of :data:`DIST_MODES`: ``"collective"`` when every
    shard's winner agreed on one XLA schedule — execution then goes
    through the shard_map engine (:func:`make_distributed`), psum
    included; ``"collective-pallas"`` when they agreed on one *Pallas*
    schedule whose plan passes :func:`stackable_plan` (the fused axis
    is harmonized to the majority winner — a lowering detail timing
    noise may split across shards, never a routing forfeit) — one
    generated-kernel trace inside shard_map
    (:func:`make_distributed_pallas`), psum included; otherwise ``"replay"``: each shard executes its own
    tuned plan via its compiled backend (``reference``/``xla``/
    ``pallas``) and the dense partials are summed host-side (exact,
    because shards keep global coordinates).  Calling the object always
    returns the **global** dense output, so results are directly
    comparable against ``reference_execute``/``dense_oracle``.
    """

    spec: SpTTNSpec
    mesh: Mesh
    mode_axis: dict[int, str]
    shape: tuple[int, ...]       # global sparse-tensor shape
    shards: list[TunedShard]
    mode: str
    cyclic: bool = True
    collective: DistributedSpTTN | None = None
    # pattern-static undo-relabeling gathers, built lazily once
    _undo: list | None = dataclasses.field(default=None, repr=False,
                                           compare=False)

    @property
    def plans(self) -> list[SpTTNPlan | None]:
        return [sh.plan for sh in self.shards]

    @property
    def backends(self) -> list[str | None]:
        return [None if sh.plan is None else sh.plan.backend
                for sh in self.shards]

    @property
    def nnz_per_shard(self) -> list[int]:
        return [sh.nnz for sh in self.shards]

    def __call__(self, factors: Mapping) -> np.ndarray:
        if self.mode in ("collective", "collective-pallas"):
            out = np.asarray(self.collective(factors))
            if self._undo is None:
                self._undo = undo_cyclic_plan(self.spec, self.mode_axis,
                                              self.mesh, self.shape,
                                              cyclic=self.cyclic)
            for axis, take in self._undo:
                out = np.take(out, take, axis=axis)
            return out
        total = None
        for sh in self.shards:
            if sh.fn is None:
                continue
            part = np.asarray(sh.fn(factors))
            total = part if total is None else total + part
        if total is None:       # all shards empty: zero output
            dims = self.spec.dims
            total = np.zeros([dims[i] for i in self.spec.output.indices],
                             np.float32)
        return total


def _annotate_dist_mode(cache_dir, shards, mode: str) -> None:
    """Record the distributed mode the tuned plans were routed through
    into each live shard's plan-cache entry meta — the tuner's timings
    then tell the whole story (which backend won AND how it executed on
    the mesh) without re-deriving the routing."""
    if cache_dir is None:
        return
    from repro.autotune.cache import PlanCache
    cache = PlanCache(cache_dir)
    for sh in shards:
        key = getattr(sh.stats, "cache_key", "") if sh.stats else ""
        if key:
            cache.annotate(key, dist_mode=mode)


def make_distributed_tuned(spec: SpTTNSpec, coo: COOTensor, mesh: Mesh,
                           mode_axis: Mapping[int, str],
                           cache_dir: str | None = None,
                           tuner=None, cyclic: bool = True,
                           prefer_collective: bool = True,
                           **executor_kwargs) -> DistributedPlanReplay:
    """Partition ``coo`` over the mesh and replay a tuned plan per shard.

    The end-to-end pipeline of DESIGN.md §7: partition the nonzeros over
    the partitioned mesh axes → per shard, run (or cache-hit) the
    autotuner on the *shard's local nnz profile* under a mesh-extended
    cache key (:func:`shard_mesh_key` via ``TunerConfig.mesh``) → execute
    every shard through its winner's backend → reduce the partial
    outputs.  When all shards agree on one schedule (the common case for
    well-balanced partitions) and ``prefer_collective`` is set, the
    reduction is a shard_map psum: XLA winners go through
    :func:`make_distributed`, Pallas winners whose plan passes
    :func:`stackable_plan` through :func:`make_distributed_pallas` (one
    kernel trace for all shards); heterogeneous or non-stackable winners
    replay shard-by-shard with a host-side sum.  The chosen mode is
    recorded into each live shard's plan-cache entry meta
    (``dist_mode``) when ``cache_dir`` is given.

    ``tuner`` is a :class:`repro.autotune.TunerConfig` template (its
    ``mesh`` field is overwritten per shard); extra kwargs reach the
    Pallas code generator for pallas-backend shards (``block``,
    ``strategy``).  Same-sparsity (TTTP-like) outputs need the collective
    layout to reassemble leaf values and are rejected here — use
    :func:`make_distributed`.
    """
    if spec.output_is_sparse:
        raise ValueError(
            "make_distributed_tuned requires a dense output; same-sparsity "
            "outputs (TTTP-like) reassemble leaf values through "
            "make_distributed's stacked layout instead")
    from repro.autotune import TunerConfig, tune
    from repro.core.executor import make_executor

    base = tuner if tuner is not None else TunerConfig()
    nparts = {m: int(mesh.shape[ax]) for m, ax in mode_axis.items()}
    shards: list[TunedShard] = []
    for s, local in enumerate(partition_nonzeros(coo, nparts,
                                                 cyclic=cyclic)):
        if local.nnz == 0:
            shards.append(TunedShard(s, 0, None, None))
            continue
        csf_s = build_csf(local)
        cfg = dataclasses.replace(
            base, mesh=shard_mesh_key(mesh, mode_axis, s))
        plan_s, stats_s = tune(spec, csf=csf_s, cache_dir=cache_dir,
                               tuner=cfg)
        shards.append(TunedShard(s, csf_s.nnz, plan_s, stats_s, csf=csf_s))

    live = [sh for sh in shards if sh.plan is not None]
    dist = DistributedPlanReplay(spec=spec, mesh=mesh,
                                 mode_axis=dict(mode_axis), shape=coo.shape,
                                 shards=shards, mode="replay", cyclic=cyclic)
    if not live:
        return dist              # degenerate: empty tensor, zero output

    # static pre-flight on every live shard's winner: a corrupt cache
    # entry (doctored mesh context, illegal axes) fails here with a
    # structured diagnostic instead of deep inside a shard's lowering
    from repro.analysis import verify_plan
    for sh in live:
        verify_plan(sh.plan).raise_if_error(
            f"make_distributed_tuned[shard {sh.index}]")

    first = live[0].plan
    # homogeneity on the schedule (path/order/backend).  The fused axis
    # is deliberately NOT part of it: fused-vs-staged is a lowering
    # detail of the same plan whose per-shard winner is decided by
    # measured timings, so on near-tied candidates shards split on it
    # by noise — forfeiting collective routing over that would make the
    # routed mode nondeterministic run to run.  fusibility depends only
    # on (spec, path), identical across shards, so harmonizing to the
    # majority winner is always legal; everything else heterogeneous
    # still falls back to replay.
    homogeneous = all(
        (sh.plan.path, sh.plan.order, sh.plan.backend)
        == (first.path, first.order, first.backend)
        for sh in live)
    fused_votes = sum(1 for sh in live if sh.plan.fused)
    fused = homogeneous and fused_votes * 2 > len(live)
    if first.fused != fused:
        first = dataclasses.replace(first, fused=fused)
    if prefer_collective and homogeneous and first.backend == "xla":
        dist.mode = "collective"
        dist.collective = make_distributed(spec, first, coo, mesh,
                                           dict(mode_axis), cyclic=cyclic)
        for sh in live:          # shard_map holds its own stacked layout
            sh.csf = None
        _annotate_dist_mode(cache_dir, live, dist.mode)
        return dist
    if (prefer_collective and homogeneous and first.backend == "pallas"
            and stackable_plan(spec, first.path, fused=first.fused)):
        # homogeneous TPU-Pallas winners: one kernel trace for all
        # shards, replaying the tuned fused/block axes from the cache
        # entries.  Deliberately "pallas" only, not PALLAS_BACKENDS: the
        # stacked engine's one-trace-many-shards trick rides the TPU
        # lowering's scalar-prefetched layouts; pallas-gpu winners take
        # the per-shard replay below (split-K needs no stacking to be
        # grid-parallel)
        dist.mode = "collective-pallas"
        dist.collective = make_distributed_pallas(
            spec, first, coo, mesh, dict(mode_axis), cyclic=cyclic,
            **executor_kwargs)
        for sh in live:          # shard_map holds its own stacked layout
            sh.csf = None
        _annotate_dist_mode(cache_dir, live, dist.mode)
        return dist

    _annotate_dist_mode(cache_dir, live, "replay")
    for sh in live:
        pallas_kind = sh.plan.backend in PALLAS_BACKENDS
        kw = dict(executor_kwargs) if pallas_kind else {}
        if pallas_kind and sh.plan.fused:
            # the shard's winner used the fused chain lowering
            # (DESIGN.md §6); replay through the same strategy
            kw.setdefault("strategy", "fused")
        if pallas_kind and getattr(sh.plan, "block", None):
            # ... and with the shard's tuned fiber block size (DESIGN.md
            # §8) — shards may win at different blocks on skewed
            # partitions, so the knob is per shard, not per mesh
            kw.setdefault("block", sh.plan.block)
        ex = make_executor(spec, sh.plan.path, sh.plan.order,
                           backend=sh.plan.backend, **kw)
        if sh.plan.backend == "reference":
            sh.fn = (lambda f, ex=ex, csf=sh.csf: ex(csf, f))
        else:
            sh.arrays = CSFArrays.from_csf(sh.csf)
            sh.arrays.host = None    # device arrays suffice for xla/pallas
            sh.csf = None
            sh.fn = jax.jit(lambda f, ex=ex, arrays=sh.arrays:
                            ex(arrays, f))
    return dist


def gather_sparse_values(dist: DistributedSpTTN, out_stacked) -> np.ndarray:
    """Reassemble a same-sparsity (TTTP-like) output into the original COO
    nonzero order from the stacked per-shard value layout."""
    vals = np.asarray(out_stacked).reshape(len(dist.nnz_per_shard),
                                           dist.max_nnz)
    total = int(sum(dist.nnz_per_shard))
    out = np.zeros(total, vals.dtype)
    for s, n in enumerate(dist.nnz_per_shard):
        ids = dist.perm[sum(dist.nnz_per_shard[:s]):
                        sum(dist.nnz_per_shard[:s]) + n]
        out[ids] = vals[s, :n]
    return out


def undo_cyclic_plan(spec: SpTTNSpec, mode_axis, mesh, shape,
                     cyclic: bool = True) -> list[tuple[int, np.ndarray]]:
    """Pattern-static (axis, take) gathers inverting the cyclic row
    relabeling on partitioned output modes — compute once, apply per
    call (the stacked layout is [part, local]; global = local*nparts +
    part)."""
    sp_inds = spec.sparse_indices
    plan = []
    for m, ax in mode_axis.items():
        ind = sp_inds[m]
        if ind not in spec.output.indices:
            continue
        axis = spec.output.indices.index(ind)
        nparts = mesh.shape[ax]
        I = shape[m]
        local = -(-I // nparts)
        if not cyclic:
            plan.append((axis, np.arange(I)))
            continue
        take = np.zeros(I, np.int64)
        for p in range(nparts):
            for l in range(local):
                g = l * nparts + p
                if g < I:
                    take[g] = p * local + l
        plan.append((axis, take))
    return plan


def undo_cyclic(out: np.ndarray, spec: SpTTNSpec, mode_axis, mesh,
                shape, cyclic: bool = True) -> np.ndarray:
    """Invert the cyclic row relabeling on output modes for comparison."""
    res = out
    for axis, take in undo_cyclic_plan(spec, mode_axis, mesh, shape,
                                       cyclic=cyclic):
        res = np.take(res, take, axis=axis)
    return res
