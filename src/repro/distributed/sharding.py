"""Logical-axis sharding rules (MaxText-style) and activation constraints.

Parameters carry *logical* axis names (see models/layers.py); a rules table
maps them to mesh axes per mesh layout.  Defaults implement:

  FSDP   — weights sharded over the data axes on their 'embed'/'ffn' dim
  TP     — heads / ffn-hidden / experts / vocab sharded over 'model'
  DP     — batch over ('pod','data'); long-context decode shards the KV/seq
           axis over 'data' instead (flash-decode partial-softmax psum)

``set_mesh_context`` installs a mesh + rules for the duration of a lowering;
``shard_activation`` is a no-op outside a mesh context so models stay pure.
"""
from __future__ import annotations

import contextlib
import threading
from collections.abc import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


# --------------------------------------------------------------------------- #
# rules
# --------------------------------------------------------------------------- #
def default_rules(multi_pod: bool, shape_kind: str = "train",
                  seq_shard: bool = False,
                  preset: str = "2d") -> dict[str, object]:
    """Sharding presets.

    '2d' (default)    — DP/FSDP over data axes, TP/EP over 'model'.
    'seq_parallel'    — sequence sharded over 'model', weights replicated
                        across it (vocab stays model-sharded).  The right
                        scheme for models too narrow for 16-way TP (heads
                        or ffn not divisible): attention/MLP compute
                        partitions over tokens instead of being replicated,
                        and the per-layer partial-sum all-reduces disappear
                        (see EXPERIMENTS.md §Perf).
    """
    data_axes = ("pod", "data") if multi_pod else ("data",)
    seqp = preset == "seq_parallel"
    tp = None if seqp else "model"
    rules: dict[str, object] = {
        # parameter logical axes
        "vocab": "model",
        "embed": data_axes,          # FSDP shard on the embed dim
        "ffn": tp,
        "q_heads": tp,
        "kv_heads": tp,
        "experts": "model",          # EP stays even under seq_parallel
        "lora": None,
        "heads": tp,
        "head_dim": None,
        "conv": None,
        "layers": None,
        # activation logical axes
        "act_batch": data_axes,
        "act_seq": "model" if seqp else ("data" if seq_shard else None),
        "act_embed": None,
    }
    return rules


def spec_for(logical: Sequence[str] | None,
             rules: Mapping[str, object]) -> P:
    if logical is None:
        return P()
    return P(*[rules.get(ax, None) for ax in logical])


def tree_sharding(params_or_shapes, spec_tree, rules, mesh: Mesh):
    """NamedSharding tree for a params tree (arrays or ShapeDtypeStructs)."""
    is_spec = lambda s: isinstance(s, tuple) and all(
        isinstance(x, (str, type(None))) for x in s)

    def one(spec, arr):
        parts = []
        used: set[str] = set()
        for dim, ax in zip(arr.shape, spec):
            m = rules.get(ax, None)
            if m is None:
                parts.append(None)
                continue
            axes = (m,) if isinstance(m, str) else tuple(m)
            # a mesh axis may appear at most once per spec: earlier
            # (higher-priority) logical dims win, e.g. experts>ffn for EP
            if used & set(axes):
                parts.append(None)
                continue
            extent = 1
            for a in axes:
                extent *= mesh.shape[a]
            if extent > 0 and dim % extent == 0 and dim >= extent:
                parts.append(m)
                used |= set(axes)
            else:
                parts.append(None)
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, spec_tree, params_or_shapes, is_leaf=is_spec)


# --------------------------------------------------------------------------- #
# activation constraint context
# --------------------------------------------------------------------------- #
@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Mapping[str, object]):
    _ctx.mesh = mesh
    _ctx.rules = rules
    try:
        yield
    finally:
        _ctx.mesh = None
        _ctx.rules = None


_ACT_SPECS = {
    # (batch, seq, embed)
    "btd": ("act_batch", "act_seq", "act_embed"),
    # (batch, seq, heads, head_dim)
    "bthd": ("act_batch", "act_seq", "heads", None),
    # MoE expert buffers: (experts, capacity, embed).  Explicit pinning was
    # tried and REFUTED twice (EXPERIMENTS.md §Perf granite iterations 1-2:
    # experts->model regressed 2.4x, capacity->data regressed collectives
    # 20x) — GSPMD's inferred placement wins; leave unconstrained.
    "ecd": (None, None, None),
}


def replicate(x):
    """Constrain to fully-replicated (no-op outside a mesh context).
    Used to force a cheap table all-gather before an embedding lookup —
    GSPMD otherwise lowers the gather from a vocab-sharded table as a
    one-hot matmul (~10x the model's FLOPs at 1M tokens; §Perf)."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim))))


def shard_activation(x, kind: str):
    mesh = getattr(_ctx, "mesh", None)
    rules = getattr(_ctx, "rules", None)
    if mesh is None or rules is None:
        return x
    logical = _ACT_SPECS.get(kind)
    if logical is None or len(logical) != x.ndim:
        return x
    parts = []
    for dim, ax in zip(x.shape, logical):
        m = rules.get(ax, None) if ax else None
        if m is None:
            parts.append(None)
            continue
        axes = (m,) if isinstance(m, str) else tuple(m)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        parts.append(m if dim % extent == 0 and dim >= extent else None)
    if all(p is None for p in parts):
        # a fully-None spec is NOT a no-op: it would FORCE replication
        # (measured 17x per-layer FLOP blowup on the MoE buffers — §Perf)
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
