from repro.distributed import collectives, sharding, spttn_dist
from repro.distributed.spttn_dist import (DIST_MODES, DistributedPlanReplay,
                                          make_distributed,
                                          make_distributed_pallas,
                                          make_distributed_tuned,
                                          partition_mesh,
                                          partition_nonzeros,
                                          shard_mesh_key, stackable_plan,
                                          unpad_local_csf)

__all__ = [
    "collectives", "sharding", "spttn_dist", "DIST_MODES",
    "DistributedPlanReplay", "make_distributed", "make_distributed_pallas",
    "make_distributed_tuned", "partition_mesh", "partition_nonzeros",
    "shard_mesh_key", "stackable_plan", "unpad_local_csf",
]
