from repro.distributed import collectives, sharding, spttn_dist

__all__ = ["collectives", "sharding", "spttn_dist"]
