from repro.distributed import collectives, sharding, spttn_dist
from repro.distributed.spttn_dist import (DistributedPlanReplay,
                                          make_distributed,
                                          make_distributed_tuned,
                                          partition_nonzeros,
                                          shard_mesh_key)

__all__ = [
    "collectives", "sharding", "spttn_dist", "DistributedPlanReplay",
    "make_distributed", "make_distributed_tuned", "partition_nonzeros",
    "shard_mesh_key",
]
