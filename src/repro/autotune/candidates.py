"""Model-pruned candidate generation (paper §4.1 + SparseAuto's hybrid).

The full loop-nest space is O((n!)^2/(n·2^n) · prod |I_i|!/k_i!) — far too
large to time exhaustively, but the paper's cost models rank it well enough
that the true optimum is almost always near the top.  We therefore keep,
per min-depth contraction path, the Algorithm-1 (DP) optimal order plus a
few enumerated alternatives, rank everything by (model cost, sparse-aware
FLOPs), and hand only the head of that ranking to the measuring stage.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections.abc import Mapping, Sequence

from repro.core import cost as cost_lib
from repro.core.cost import ConstrainedBlas, TreeCost, path_flops
from repro.core.loopnest import LoopOrder, enumerate_orders
from repro.core.order_dp import OrderDP
from repro.core.paths import ContractionPath, min_depth_paths, path_depth
from repro.core.spec import SpTTNSpec


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One schedule the tuner may measure, with its model scores.

    ``backend`` is the execution engine the schedule would run on — a
    full autotuning axis: the same (path, order) may win on one backend
    and lose on another, so each (schedule, backend) pair is measured
    separately and the winner's backend lands in the plan cache.
    ``fused`` is the Pallas backend's second axis (DESIGN.md §6): run
    detected reducing chains as one multi-level kernel (True) or as
    staged per-term kernels (False); it is only expanded for schedules
    whose path actually contains a provably fusible chain.  ``block`` is
    the Pallas backend's third axis (DESIGN.md §8): the fiber block size
    of every generated stage — a swept value is always a positive
    multiple of 8 (the TPU sublane tile); 0 means "engine default" and
    is what non-Pallas candidates carry.
    """

    path: ContractionPath
    order: LoopOrder
    cost: float          # model cost (TreeCost.evaluate — order-dependent)
    flops: float         # sparse-aware FLOP model (path-dependent)
    backend: str = "xla"
    fused: bool = False
    block: int = 0       # 0 = engine default (non-Pallas candidates)

    @property
    def key(self) -> str:
        terms = "|".join(str(t) for t in self.path)
        orders = ";".join(",".join(a) for a in self.order)
        fz = "+fused" if self.fused else ""
        blk = f"%b{self.block}" if self.block else ""
        return f"{terms}#{orders}@{self.backend}{fz}{blk}"


def default_nnz_levels(spec: SpTTNSpec) -> dict[int, int]:
    """Density-agnostic default (same as the planner's): nnz^(I1..Ip) grows
    with the prefix index space."""
    prod = 1
    levels = {0: 1}
    for p, ind in enumerate(spec.sparse_indices, start=1):
        prod *= spec.dims[ind]
        levels[p] = prod
    return levels


def generate_candidates(spec: SpTTNSpec,
                        cost: TreeCost | None = None,
                        nnz_levels: Mapping[int, int] | None = None,
                        max_paths: int | None = 16,
                        depth_slack: int = 0,
                        max_candidates: int = 8,
                        orders_per_path: int = 3,
                        backends: Sequence[str] = ("xla",),
                        blocks: Sequence[int] | None = None
                        ) -> list[Candidate]:
    """Generate the model-pruned candidate set, best-ranked first.

    Per path: the DP-optimal order always survives; ``orders_per_path - 1``
    further orders come from exhaustive enumeration (cheap for the paper's
    kernel sizes).  The final ranking is (cost, flops) ascending, truncated
    to ``max_candidates``, then expanded across ``backends`` (the cost
    models are backend-blind, so every surviving schedule is measured on
    every requested engine; the head of the expansion — best model score
    on ``backends[0]`` — is the pure-model pick).  On an all-dense
    network the Pallas backend degrades to XLA (the generator emits no
    sparse stages there), so it is folded into the XLA candidate rather
    than measured twice — the expansion is never empty.  Pallas
    candidates whose path contains a provably fusible reducing chain
    (``fusible_chains``) are additionally expanded across the ``fused``
    axis, so the staged and single-kernel chain lowerings compete on
    wall clock.

    ``blocks`` is the Pallas block-size grid (DESIGN.md §8): every
    pallas candidate is expanded once per grid value, so the fiber block
    size competes on wall clock like any other axis and the winner's
    block persists with the plan.  Entries must be positive multiples of
    8 (the TPU sublane tile — the pad-to-tile pass guarantees lane
    alignment but cannot repair a misaligned sublane count without
    silently changing the schedule being measured).  ``None`` means the
    single-point grid ``(DEFAULT_BLOCK,)``.
    """
    from repro.kernels.codegen.executor import DEFAULT_BLOCK
    blocks = tuple(blocks) if blocks else (DEFAULT_BLOCK,)
    bad_blocks = [b for b in blocks
                  if not isinstance(b, int) or b <= 0 or b % 8]
    if bad_blocks:
        raise ValueError(
            f"block sizes must be positive multiples of 8, got {bad_blocks}")
    cost = cost or ConstrainedBlas(bound=2)
    nnz_levels = dict(nnz_levels) if nnz_levels else default_nnz_levels(spec)
    sp = spec.sparse_indices
    seen: set[str] = set()
    out: list[Candidate] = []

    def add(path: ContractionPath, order: LoopOrder):
        c = cost.evaluate(path, order, spec.dims, sp)
        if c == cost_lib.INF:
            return
        f = path_flops(path, spec.dims, sp, nnz_levels)
        cand = Candidate(path=path, order=order, cost=c, flops=f)
        if cand.key in seen:
            return
        seen.add(cand.key)
        out.append(cand)

    for path in min_depth_paths(spec, max_paths=max_paths,
                                slack=depth_slack):
        res = OrderDP(path, cost, spec.dims, sp).solve()
        if res.order is not None and res.cost != cost_lib.INF:
            add(path, res.order)
        extra = max(0, orders_per_path - 1)
        if extra:
            for order in itertools.islice(enumerate_orders(path, sp),
                                          8 * extra):
                if len([c for c in out if c.path is path]) > extra:
                    break
                add(path, order)

    if not out:
        # constraint infeasible everywhere: fall back to minimizing buffer
        # size, which is always feasible (mirrors planner.plan's fallback)
        from repro.core.cost import MaxBufferSize
        if not isinstance(cost, MaxBufferSize):
            return generate_candidates(
                spec, cost=MaxBufferSize(), nnz_levels=nnz_levels,
                max_paths=max_paths, depth_slack=depth_slack,
                max_candidates=max_candidates,
                orders_per_path=orders_per_path, backends=backends,
                blocks=blocks)
        raise ValueError(f"no feasible loop nest found for {spec}")

    out.sort(key=lambda c: (c.cost, c.flops, path_depth(c.path)))
    out = out[:max_candidates]
    from repro.core.executor import BACKENDS
    bad = [b for b in backends if b not in BACKENDS]
    if bad:
        raise ValueError(f"unknown backends {bad}; expected from {BACKENDS}")
    # lazy import: the chain detector lives with the Pallas generator but
    # is purely structural, so it costs nothing when pallas is off-axis
    from repro.analysis.diagnostics import PALLAS_BACKENDS
    from repro.kernels.codegen import fusible_chains
    expanded, seen_keys = [], set()
    for c in out:
        for b in backends:
            if b in PALLAS_BACKENDS and spec.sparse_input is None:
                b = "xla"   # identical engines on an all-dense network
            variants = (False,)
            if b in PALLAS_BACKENDS and fusible_chains(spec, c.path):
                # fusion axis: staged AND fused chain lowering
                variants = (False, True)
            # block axis: only the Pallas engines consume a block size
            blks = blocks if b in PALLAS_BACKENDS else (0,)
            for fz in variants:
                for blk in blks:
                    cand = dataclasses.replace(c, backend=b, fused=fz,
                                               block=blk)
                    if cand.key in seen_keys:
                        continue
                    seen_keys.add(cand.key)
                    expanded.append(cand)
    return expanded
