"""Empirical candidate timing (paper §4.1: 'enumeration enables
autotuning').

Each candidate is compiled through its backend's engine (``make_executor``;
XLA or generated Pallas) + jax.jit, warmed up (absorbing compile time),
then timed ``repeats`` times; the score is the median.  Early-exit
pruning: once any candidate has finished, a
later candidate whose *first* timed call already exceeds
``prune_ratio x best_median`` is abandoned — the paper's kernels make the
model ranking good enough that most losers die after one call.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Mapping, Sequence

import numpy as np

from repro.analysis.diagnostics import PALLAS_BACKENDS
from repro.autotune.candidates import Candidate
from repro.core.spec import SpTTNSpec


@dataclasses.dataclass
class MeasureConfig:
    warmup: int = 1
    repeats: int = 3
    prune_ratio: float = 2.0     # 0/inf disables early-exit pruning


@dataclasses.dataclass
class Measurement:
    candidate: Candidate
    seconds: float               # median over completed repeats
    pruned: bool = False         # abandoned after the first timed call


def synth_inputs(spec: SpTTNSpec, density: float = 0.05, seed: int = 0):
    """Deterministic measurement inputs when the caller has no data yet:
    a random sparse tensor over the spec's sparse dims + random factors.
    Determinism matters — the synthesized nnz-level profile is part of the
    plan-cache key, so a restart must resynthesize the same pattern."""
    from repro.sparse import build_csf, random_sparse
    shape = tuple(spec.dims[i] for i in spec.sparse_indices)
    csf = build_csf(random_sparse(shape, density, seed=seed))
    factors = synth_factors(spec, seed=seed)
    return csf, factors


def synth_factors(spec: SpTTNSpec, seed: int = 0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    factors = {}
    for t in spec.inputs:
        if t.is_sparse:
            continue
        shape = tuple(spec.dims[i] for i in t.indices)
        factors[t.name] = jnp.asarray(
            rng.standard_normal(shape).astype(np.float32))
    return factors


def measure_candidates(spec: SpTTNSpec,
                       candidates: Sequence[Candidate],
                       arrays,
                       factors: Mapping[str, object],
                       config: MeasureConfig | None = None,
                       stats=None) -> list[Measurement]:
    """Time every candidate; returns measurements sorted fastest-first.

    ``arrays`` is a device-resident :class:`CSFArrays`.  ``stats`` (a
    :class:`~repro.autotune.tuner.SearchStats`) is incremented in place so
    callers can assert how much empirical work a search performed.
    """
    import jax

    from repro.core.executor import make_executor

    config = config or MeasureConfig()
    results: list[Measurement] = []
    best: float | None = None

    def run(fn) -> float:
        t0 = time.perf_counter()
        out = fn(factors)
        jax.block_until_ready(out)
        if stats is not None:
            stats.executions += 1
        return time.perf_counter() - t0

    for cand in candidates:
        backend = getattr(cand, "backend", "xla")
        kwargs = {}
        if getattr(cand, "fused", False):
            kwargs["strategy"] = "fused"   # single-kernel chain lowering
        if backend in PALLAS_BACKENDS and getattr(cand, "block", 0):
            kwargs["block"] = cand.block   # swept block axis (DESIGN.md §8)
        ex = make_executor(spec, cand.path, cand.order, backend=backend,
                           **kwargs)
        fn = jax.jit(lambda f, ex=ex: ex(arrays, f))
        for _ in range(config.warmup):
            run(fn)
        if stats is not None:
            stats.candidates_timed += 1
        first = run(fn)
        if (best is not None and config.prune_ratio
                and first > config.prune_ratio * best):
            results.append(Measurement(cand, first, pruned=True))
            if stats is not None:
                stats.pruned += 1
            continue
        times = [first] + [run(fn) for _ in range(config.repeats - 1)]
        med = float(np.median(times))
        results.append(Measurement(cand, med))
        best = med if best is None else min(best, med)

    # pruned entries carry a single first-call sample, not a median —
    # they must never outrank (or tie) a fully measured candidate, so
    # they sort strictly after every completed measurement
    results.sort(key=lambda m: (m.pruned, m.seconds))
    return results
