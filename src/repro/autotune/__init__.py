"""Autotuning runtime with a persistent plan cache (DESIGN.md §4).

Entry points:
  * :func:`tune` — model-pruned enumeration + empirical timing; the engine
    behind ``plan(spec, autotune=True, cache_dir=...)``.
  * :class:`PlanCache` / :func:`cache_key` — disk persistence keyed by
    (spec signature, CSF nnz-level profile, device kind).
"""
from repro.autotune.cache import (CACHE_VERSION, PlanCache, cache_key,
                                  device_kind, spec_signature)
from repro.autotune.candidates import (Candidate, default_nnz_levels,
                                       generate_candidates)
from repro.autotune.measure import (MeasureConfig, Measurement,
                                    measure_candidates, synth_factors,
                                    synth_inputs)
from repro.autotune.tuner import (SearchStats, TunerConfig,
                                  default_backends, tune)

__all__ = [
    "CACHE_VERSION", "PlanCache", "cache_key", "device_kind",
    "spec_signature", "Candidate", "default_nnz_levels",
    "generate_candidates", "MeasureConfig", "Measurement",
    "measure_candidates", "synth_factors", "synth_inputs",
    "SearchStats", "TunerConfig", "default_backends", "tune",
]
