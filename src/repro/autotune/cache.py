"""Disk-backed plan cache (DESIGN.md §4).

Plans depend only on the *fixed* sparsity pattern (paper §1), never on
values, so a tuned schedule is reusable across process restarts and across
tensors sharing a pattern.  The key is a content hash of

  (spec signature, CSF nnz-level profile, device kind, backend axis,
   mesh/shard context, CACHE_VERSION)

- spec signature: canonical kernel string incl. names, dims, sparse marker;
- nnz-level profile: {p: nnz^(I1..Ip)} — the exact quantity every cost
  model consumes, so two patterns with equal profiles are planning-
  equivalent by construction (values never enter);
- device kind: platform + device model, since the empirically best nest is
  hardware-specific;
- mesh/shard context: mesh shape + partitioned axes + shard index for a
  distributed shard-local search (None for single-device), so a sharded
  pattern never reuses a single-device winner (DESIGN.md §7);
- CACHE_VERSION: bumped whenever plan semantics / serialization change —
  the invalidation rule for stale entries (old files are simply unmatched,
  never read).

Entries are one JSON file per key, written atomically (tmp + rename) so a
crashed search never leaves a torn plan.  A corrupt/unreadable entry is
treated as a miss and overwritten by the next search.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Mapping

from repro.core.spec import SpTTNSpec

# v2: plans carry a tuned ``backend`` (PLAN_JSON_VERSION 2).  v3: the key
# gains a ``mesh`` component (mesh shape + partitioned axes + shard index,
# DESIGN.md §7) and plans carry the mesh/shard fields (PLAN_JSON_VERSION
# 3).  v4: the Pallas fusion axis — plans carry ``fused`` (PLAN_JSON_VERSION
# 4) and entries stamp ``cache_version`` so a stale-but-parseable file is
# an explicit miss, not a downstream schema error.  v5: the Pallas block
# axis (DESIGN.md §8) — the key gains a ``blocks`` grid component and
# plans carry the winner's ``block`` (PLAN_JSON_VERSION 5).  Older entries
# deserialize to a different schema and must be unmatched, never read.
CACHE_VERSION = 5


def spec_signature(spec: SpTTNSpec) -> str:
    """Canonical kernel signature: operands (with sparse markers) + dims."""
    ins = ",".join(
        f"{t.name}{'*' if t.is_sparse else ''}({','.join(t.indices)})"
        for t in spec.inputs)
    out = f"{spec.output.name}({','.join(spec.output.indices)})"
    dims = ",".join(f"{k}={spec.dims[k]}" for k in sorted(spec.dims))
    return f"{ins}->{out}|{dims}"


def device_kind() -> str:
    import jax
    d = jax.devices()[0]
    return f"{d.platform}:{d.device_kind}"


def cache_key(spec: SpTTNSpec,
              nnz_levels: Mapping[int, int],
              device: str | None = None,
              backends: tuple[str, ...] = ("xla",),
              mesh: Mapping | None = None,
              blocks: tuple[int, ...] | None = None) -> str:
    """``backends`` is the tuner's engine search axis: a plan tuned under
    a forced/narrower axis (e.g. ``("pallas",)``) must never be served to
    a search over a different axis, so the axis is part of the key.

    ``mesh`` is the distributed shard context (DESIGN.md §7): any JSON-able
    mapping naming the mesh shape, the mode→axis partitioning, and the
    shard — e.g. the output of
    :func:`repro.distributed.spttn_dist.shard_mesh_key`.  ``None`` means
    single-device.  Because the component is part of the hashed document, a
    sharded pattern can never be served a single-device winner (or a winner
    tuned for a different mesh axis), even when the local nnz profile
    happens to coincide.

    ``blocks`` is the Pallas block-size grid swept by the search
    (DESIGN.md §8) — the same narrowing rule as ``backends``: a winner
    found over one grid must never be served to a search over another.
    ``None`` (the default single-point grid) hashes distinctly from any
    explicit grid.

    >>> from repro.core import spec as S
    >>> spec = S.mttkrp(8, 6, 5, 4)
    >>> levels = {0: 1, 1: 8, 2: 20, 3: 40}
    >>> single = cache_key(spec, levels, "cpu:x")
    >>> shard0 = cache_key(spec, levels, "cpu:x",
    ...                    mesh={"mesh_shape": {"data": 4},
    ...                          "mode_axis": {"0": "data"}, "shard": 0})
    >>> single == shard0
    False
    >>> single == cache_key(spec, levels, "cpu:x", blocks=(128, 256))
    False
    >>> len(single)
    64
    """
    doc = {
        "version": CACHE_VERSION,
        "spec": spec_signature(spec),
        "nnz_levels": {str(k): int(v)
                       for k, v in sorted(nnz_levels.items())},
        "device": device if device is not None else device_kind(),
        "backends": list(backends),
        "mesh": None if mesh is None else dict(mesh),
        "blocks": None if blocks is None else [int(b) for b in blocks],
    }
    blob = json.dumps(doc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class PlanCache:
    """One JSON file per plan under ``cache_dir``.

    >>> import tempfile
    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> cache = PlanCache(tempfile.mkdtemp())
    >>> p = plan(S.mttkrp(8, 6, 5, 4))
    >>> path = cache.put("some-key", p)
    >>> cache.get("some-key") == p
    True
    >>> cache.get("missing") is None
    True
    """

    cache_dir: str

    def __post_init__(self):
        os.makedirs(self.cache_dir, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"plan-{key}.json")

    def get(self, key: str):
        """Returns the cached SpTTNPlan or None (miss / corrupt entry).

        The entry's ``cache_version`` is checked explicitly before the
        plan document is deserialized: a stale-but-parseable file (e.g. a
        v3 entry surviving at a colliding name, or a hand-restored
        backup) is a clean miss rather than a downstream schema error.
        """
        from repro.core.executor import plan_from_dict
        try:
            with open(self._path(key)) as f:
                doc = json.load(f)
            if doc.get("cache_version") != CACHE_VERSION:
                return None
            return plan_from_dict(doc["plan"])
        except (OSError, ValueError, KeyError, TypeError, AttributeError):
            # any malformed entry — invalid JSON, wrong shape, foreign
            # writer — is a miss; the next search overwrites it
            return None

    def put(self, key: str, plan, meta: Mapping | None = None) -> str:
        from repro.core.executor import plan_to_dict
        doc = {"cache_version": CACHE_VERSION,
               "plan": plan_to_dict(plan), "meta": dict(meta or {})}
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1)
            os.replace(tmp, path)   # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path
