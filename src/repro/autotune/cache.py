"""Disk-backed plan cache (DESIGN.md §4).

Plans depend only on the *fixed* sparsity pattern (paper §1), never on
values, so a tuned schedule is reusable across process restarts and across
tensors sharing a pattern.  The key is a content hash of

  (spec signature, CSF nnz-level profile, device kind, backend axis,
   mesh/shard context, profile-quantization scheme, CACHE_VERSION)

- spec signature: canonical kernel string incl. names, dims, sparse marker;
- nnz-level profile: {p: nnz^(I1..Ip)} — the exact quantity every cost
  model consumes, so two patterns with equal profiles are planning-
  equivalent by construction (values never enter);
- device kind: platform + device model, since the empirically best nest is
  hardware-specific;
- mesh/shard context: mesh shape + partitioned axes + shard index for a
  distributed shard-local search (None for single-device), so a sharded
  pattern never reuses a single-device winner (DESIGN.md §7);
- profile-quantization scheme: ``"exact"`` for the classic per-pattern
  key; a bucketing scheme name (``"log2"``) for the serving-stream key
  over a quantized profile, so a stream of perturbed patterns shares one
  tuned plan (DESIGN.md §9) without ever colliding with an exact entry;
- CACHE_VERSION: bumped whenever plan semantics / serialization change —
  the invalidation rule for stale entries (old files are simply unmatched,
  never read).

Entries are one JSON file per key, written atomically (tmp + rename) so a
crashed search never leaves a torn plan.  A corrupt/unreadable entry is
treated as a miss and overwritten by the next search.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import tempfile
from collections.abc import Mapping

from repro.core.spec import SpTTNSpec

# v2: plans carry a tuned ``backend`` (PLAN_JSON_VERSION 2).  v3: the key
# gains a ``mesh`` component (mesh shape + partitioned axes + shard index,
# DESIGN.md §7) and plans carry the mesh/shard fields (PLAN_JSON_VERSION
# 3).  v4: the Pallas fusion axis — plans carry ``fused`` (PLAN_JSON_VERSION
# 4) and entries stamp ``cache_version`` so a stale-but-parseable file is
# an explicit miss, not a downstream schema error.  v5: the Pallas block
# axis (DESIGN.md §8) — the key gains a ``blocks`` grid component and
# plans carry the winner's ``block`` (PLAN_JSON_VERSION 5).  v6: the
# serving hot path (DESIGN.md §9) — the key gains a ``profile`` component
# naming how the nnz-level profile was quantized (``"exact"`` for the
# classic per-pattern key, a bucketing scheme name for the shared
# serving-stream key), so a bucketed winner can never shadow an exact one
# and vice versa.  v7: plan JSON grew the memory-budget slicing fields
# (``slice_mode``/``slice_chunks``, PLAN_JSON_VERSION 6, DESIGN.md §10) —
# the budget itself is deliberately NOT a key component (the cache stores
# the unsliced schedule; the slice decision is re-derived per call), but
# v6 entries carry v5 plan docs and must be unmatched, never read.
CACHE_VERSION = 7

# Profile-quantization schemes for serving streams (DESIGN.md §9): a
# stream of near-identical patterns (MoE routing masks, per-user masks)
# has a *different* exact profile per request, so the exact key is a
# guaranteed cold miss.  Bucketing quantizes each level count before
# keying, collapsing the stream onto one tuned plan.
BUCKET_SCHEMES = ("log2",)


def bucket_nnz_levels(nnz_levels: Mapping[int, int],
                      scheme: str = "log2") -> dict[int, int]:
    """Quantize an nnz-level profile for a bucketed cache key.

    ``log2`` rounds each level count to the nearest power of two, so two
    profiles land in the same bucket iff every level agrees within a
    factor of ~sqrt(2) of a common power of two — and therefore any two
    same-bucket profiles differ by at most 2x per level, which bounds
    how far a reused plan's FLOP estimate can drift (the tuner's
    bucketed-reuse guard leans on this).

    >>> bucket_nnz_levels({0: 1, 1: 100, 2: 1000, 3: 0})
    {0: 1, 1: 128, 2: 1024, 3: 0}
    >>> bucket_nnz_levels({1: 100}) == bucket_nnz_levels({1: 170})
    True
    >>> bucket_nnz_levels({1: 100}) == bucket_nnz_levels({1: 200})
    False
    """
    if scheme not in BUCKET_SCHEMES:
        raise ValueError(f"unknown bucketing scheme {scheme!r}; expected "
                         f"one of {BUCKET_SCHEMES}")
    out = {}
    for p, n in nnz_levels.items():
        n = int(n)
        out[int(p)] = 0 if n <= 0 else 1 << max(0, round(math.log2(n)))
    return out


def spec_signature(spec: SpTTNSpec) -> str:
    """Canonical kernel signature: operands (with sparse markers) + dims."""
    ins = ",".join(
        f"{t.name}{'*' if t.is_sparse else ''}({','.join(t.indices)})"
        for t in spec.inputs)
    out = f"{spec.output.name}({','.join(spec.output.indices)})"
    dims = ",".join(f"{k}={spec.dims[k]}" for k in sorted(spec.dims))
    return f"{ins}->{out}|{dims}"


def device_kind() -> str:
    import jax
    d = jax.devices()[0]
    return f"{d.platform}:{d.device_kind}"


def cache_key(spec: SpTTNSpec,
              nnz_levels: Mapping[int, int],
              device: str | None = None,
              backends: tuple[str, ...] = ("xla",),
              mesh: Mapping | None = None,
              blocks: tuple[int, ...] | None = None,
              profile: str = "exact") -> str:
    """``backends`` is the tuner's engine search axis: a plan tuned under
    a forced/narrower axis (e.g. ``("pallas",)``) must never be served to
    a search over a different axis, so the axis is part of the key.

    ``mesh`` is the distributed shard context (DESIGN.md §7): any JSON-able
    mapping naming the mesh shape, the mode→axis partitioning, and the
    shard — e.g. the output of
    :func:`repro.distributed.spttn_dist.shard_mesh_key`.  ``None`` means
    single-device.  Because the component is part of the hashed document, a
    sharded pattern can never be served a single-device winner (or a winner
    tuned for a different mesh axis), even when the local nnz profile
    happens to coincide.

    ``blocks`` is the Pallas block-size grid swept by the search
    (DESIGN.md §8) — the same narrowing rule as ``backends``: a winner
    found over one grid must never be served to a search over another.
    ``None`` (the default single-point grid) hashes distinctly from any
    explicit grid.

    ``profile`` names how ``nnz_levels`` was quantized (DESIGN.md §9):
    ``"exact"`` is the classic per-pattern key; a bucketing scheme name
    (see :func:`bucket_nnz_levels`) marks a serving-stream key whose
    profile has already been bucketed — the caller passes the *bucketed*
    levels.  Keeping the scheme in the hashed document means an exact
    winner and a bucketed winner can never collide, even when the
    bucketed profile happens to equal some exact one.

    >>> from repro.core import spec as S
    >>> spec = S.mttkrp(8, 6, 5, 4)
    >>> levels = {0: 1, 1: 8, 2: 20, 3: 40}
    >>> single = cache_key(spec, levels, "cpu:x")
    >>> shard0 = cache_key(spec, levels, "cpu:x",
    ...                    mesh={"mesh_shape": {"data": 4},
    ...                          "mode_axis": {"0": "data"}, "shard": 0})
    >>> single == shard0
    False
    >>> single == cache_key(spec, levels, "cpu:x", blocks=(128, 256))
    False
    >>> bucketed = cache_key(spec, bucket_nnz_levels(levels), "cpu:x",
    ...                      profile="log2")
    >>> single == bucketed
    False
    >>> len(single)
    64
    """
    doc = {
        "version": CACHE_VERSION,
        "spec": spec_signature(spec),
        "nnz_levels": {str(k): int(v)
                       for k, v in sorted(nnz_levels.items())},
        "device": device if device is not None else device_kind(),
        "backends": list(backends),
        "mesh": None if mesh is None else dict(mesh),
        "blocks": None if blocks is None else [int(b) for b in blocks],
        "profile": str(profile),
    }
    blob = json.dumps(doc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def bucketed_cache_key(spec: SpTTNSpec,
                       nnz_levels: Mapping[int, int],
                       device: str | None = None,
                       backends: tuple[str, ...] = ("xla",),
                       mesh: Mapping | None = None,
                       blocks: tuple[int, ...] | None = None,
                       scheme: str = "log2") -> str:
    """The serving-stream key (DESIGN.md §9): :func:`cache_key` over the
    *bucketed* profile, with the scheme recorded in the hashed document.
    Two perturbed patterns whose per-level counts round to the same
    buckets share this key — and therefore one tuned plan.

    >>> from repro.core import spec as S
    >>> spec = S.mttkrp(8, 6, 5, 4)
    >>> a = bucketed_cache_key(spec, {0: 1, 1: 8, 2: 20, 3: 40}, "cpu:x")
    >>> b = bucketed_cache_key(spec, {0: 1, 1: 8, 2: 22, 3: 37}, "cpu:x")
    >>> a == b
    True
    """
    return cache_key(spec, bucket_nnz_levels(nnz_levels, scheme), device,
                     backends=backends, mesh=mesh, blocks=blocks,
                     profile=scheme)


@dataclasses.dataclass
class PlanCache:
    """One JSON file per plan under ``cache_dir``.

    >>> import tempfile
    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> cache = PlanCache(tempfile.mkdtemp())
    >>> p = plan(S.mttkrp(8, 6, 5, 4))
    >>> path = cache.put("some-key", p)
    >>> cache.get("some-key") == p
    True
    >>> cache.get("missing") is None
    True
    """

    cache_dir: str

    def __post_init__(self):
        os.makedirs(self.cache_dir, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"plan-{key}.json")

    def get(self, key: str):
        """Returns the cached SpTTNPlan or None (miss / corrupt entry).

        The entry's ``cache_version`` is checked explicitly before the
        plan document is deserialized: a stale-but-parseable file (e.g. a
        v3 entry surviving at a colliding name, or a hand-restored
        backup) is a clean miss rather than a downstream schema error.
        """
        from repro.core.executor import plan_from_dict
        try:
            with open(self._path(key)) as f:
                doc = json.load(f)
            if doc.get("cache_version") != CACHE_VERSION:
                return None
            return plan_from_dict(doc["plan"])
        except (OSError, ValueError, KeyError, TypeError, AttributeError):
            # any malformed entry — invalid JSON, wrong shape, foreign
            # writer — is a miss; the next search overwrites it
            return None

    def annotate(self, key: str, **fields) -> bool:
        """Merge ``fields`` into an existing entry's ``meta`` (atomic
        rewrite).  Returns False on a miss, a corrupt entry, or a stale
        ``cache_version`` — annotation never resurrects or creates
        entries, it only enriches live ones (e.g. the distributed router
        recording which execution mode a shard's winner was routed
        through, ``dist_mode``).

        >>> import tempfile
        >>> from repro.core import spec as S
        >>> from repro.core.planner import plan
        >>> cache = PlanCache(tempfile.mkdtemp())
        >>> _ = cache.put("k", plan(S.mttkrp(8, 6, 5, 4)),
        ...               meta={"best_us": 1.0})
        >>> cache.annotate("k", dist_mode="collective-pallas")
        True
        >>> cache.meta("k")["dist_mode"]
        'collective-pallas'
        >>> cache.meta("k")["best_us"]
        1.0
        >>> cache.annotate("missing", dist_mode="replay")
        False
        """
        path = self._path(key)
        try:
            with open(path) as f:
                doc = json.load(f)
            if doc.get("cache_version") != CACHE_VERSION:
                return False
        except (OSError, ValueError):
            return False
        meta = dict(doc.get("meta") or {})
        meta.update(fields)
        doc["meta"] = meta
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1)
            os.replace(tmp, path)   # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return True

    def meta(self, key: str) -> dict | None:
        """The entry's meta mapping (timings, annotations), or None on a
        miss/corrupt/stale entry — same miss semantics as :meth:`get`."""
        try:
            with open(self._path(key)) as f:
                doc = json.load(f)
            if doc.get("cache_version") != CACHE_VERSION:
                return None
            return dict(doc.get("meta") or {})
        except (OSError, ValueError):
            return None

    def put(self, key: str, plan, meta: Mapping | None = None) -> str:
        from repro.core.executor import plan_to_dict
        doc = {"cache_version": CACHE_VERSION,
               "plan": plan_to_dict(plan), "meta": dict(meta or {})}
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1)
            os.replace(tmp, path)   # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path
