"""Disk-backed plan cache (DESIGN.md §4).

Plans depend only on the *fixed* sparsity pattern (paper §1), never on
values, so a tuned schedule is reusable across process restarts and across
tensors sharing a pattern.  The key is a content hash of

  (spec signature, CSF nnz-level profile, device kind, CACHE_VERSION)

- spec signature: canonical kernel string incl. names, dims, sparse marker;
- nnz-level profile: {p: nnz^(I1..Ip)} — the exact quantity every cost
  model consumes, so two patterns with equal profiles are planning-
  equivalent by construction (values never enter);
- device kind: platform + device model, since the empirically best nest is
  hardware-specific;
- CACHE_VERSION: bumped whenever plan semantics / serialization change —
  the invalidation rule for stale entries (old files are simply unmatched,
  never read).

Entries are one JSON file per key, written atomically (tmp + rename) so a
crashed search never leaves a torn plan.  A corrupt/unreadable entry is
treated as a miss and overwritten by the next search.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Mapping

from repro.core.spec import SpTTNSpec

# v2: plans carry a tuned ``backend`` (PLAN_JSON_VERSION 2); v1 entries
# deserialize to a different schema and must be unmatched, never read.
CACHE_VERSION = 2


def spec_signature(spec: SpTTNSpec) -> str:
    """Canonical kernel signature: operands (with sparse markers) + dims."""
    ins = ",".join(
        f"{t.name}{'*' if t.is_sparse else ''}({','.join(t.indices)})"
        for t in spec.inputs)
    out = f"{spec.output.name}({','.join(spec.output.indices)})"
    dims = ",".join(f"{k}={spec.dims[k]}" for k in sorted(spec.dims))
    return f"{ins}->{out}|{dims}"


def device_kind() -> str:
    import jax
    d = jax.devices()[0]
    return f"{d.platform}:{d.device_kind}"


def cache_key(spec: SpTTNSpec,
              nnz_levels: Mapping[int, int],
              device: str | None = None,
              backends: tuple[str, ...] = ("xla",)) -> str:
    """``backends`` is the tuner's engine search axis: a plan tuned under
    a forced/narrower axis (e.g. ``("pallas",)``) must never be served to
    a search over a different axis, so the axis is part of the key."""
    doc = {
        "version": CACHE_VERSION,
        "spec": spec_signature(spec),
        "nnz_levels": {str(k): int(v)
                       for k, v in sorted(nnz_levels.items())},
        "device": device if device is not None else device_kind(),
        "backends": list(backends),
    }
    blob = json.dumps(doc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class PlanCache:
    """One JSON file per plan under ``cache_dir``."""

    cache_dir: str

    def __post_init__(self):
        os.makedirs(self.cache_dir, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"plan-{key}.json")

    def get(self, key: str):
        """Returns the cached SpTTNPlan or None (miss / corrupt entry)."""
        from repro.core.executor import plan_from_dict
        try:
            with open(self._path(key)) as f:
                doc = json.load(f)
            return plan_from_dict(doc["plan"])
        except (OSError, ValueError, KeyError, TypeError, AttributeError):
            # any malformed entry — invalid JSON, wrong shape, foreign
            # writer — is a miss; the next search overwrites it
            return None

    def put(self, key: str, plan, meta: Mapping | None = None) -> str:
        from repro.core.executor import plan_to_dict
        doc = {"plan": plan_to_dict(plan), "meta": dict(meta or {})}
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1)
            os.replace(tmp, path)   # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path
