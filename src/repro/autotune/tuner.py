"""Autotuning runtime: model-pruned enumeration + empirical measurement +
persistent plan cache.

This is the hybrid the paper motivates in §4.1 ("identification of the best
choice of loop nest without user guidance ... enumeration enables
autotuning") and SparseAuto / Ahrens-Kjolstad quantify: cost models prune
the combinatorial schedule space to a handful of candidates, wall-clock
measurement settles what the models cannot distinguish, and the winner is
persisted keyed by (kernel signature, sparsity profile, device) so repeated
traffic — a second process, a second tensor with the same pattern — pays
zero search cost.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Mapping

from repro.analysis.diagnostics import PALLAS_BACKENDS
from repro.autotune.cache import (PlanCache, bucket_nnz_levels,
                                  bucketed_cache_key, cache_key, device_kind)
from repro.autotune.candidates import (default_nnz_levels,
                                       generate_candidates)
from repro.autotune.measure import (MeasureConfig, measure_candidates,
                                    synth_factors, synth_inputs)
from repro.core.cost import ConstrainedBlas, TreeCost
from repro.core.spec import SpTTNSpec


@dataclasses.dataclass
class TunerConfig:
    """Search-size knobs; defaults sized for the paper's kernels (n<=6).

    ``backends`` is the engine axis of the search (``None`` resolves via
    :func:`default_backends`: XLA + generated Pallas on TPU, XLA alone
    elsewhere — interpret-mode Pallas can never win wall-clock on CPU, so
    measuring it there only slows the search; pass it explicitly to force
    a pallas-backend plan, e.g. ``backends=("pallas",)``).

    ``mesh`` is the distributed shard context for a shard-local search
    (DESIGN.md §7): a JSON-able mapping naming the mesh shape, the
    mode→axis partitioning, and the shard (see
    :func:`repro.distributed.spttn_dist.shard_mesh_key`).  It enters the
    plan-cache key — a sharded pattern never reuses a single-device
    winner — and is stamped onto the tuned plan, which persists it in
    plan JSON v3.

    ``blocks`` is the Pallas block-size grid (DESIGN.md §8): every
    pallas candidate is measured once per grid value (positive multiples
    of 8 — the TPU sublane tile), the winner's block is stamped onto the
    plan, and it persists in plan JSON v5 so replay compiles the exact
    kernels that won.  ``None`` means the single-point default grid
    ``(DEFAULT_BLOCK,)`` — block sweeping costs measurements, so opting
    into a wider grid is explicit, like forcing a backend axis.

    ``profile_bucket`` opts the search into the serving hot path
    (DESIGN.md §9): on an exact-key miss, a plan tuned for a *bucketed*
    profile (:func:`repro.autotune.cache.bucket_nnz_levels`) is reused
    when its FLOP estimate on the true profile stays within
    ``bucket_tolerance`` × the estimate it was tuned at — otherwise the
    bucket entry is ignored and a fresh search runs.  A fresh winner is
    persisted under both the exact and the bucketed key, so a stream of
    perturbed patterns pays one search, not one per pattern.  ``None``
    (the default) keeps the classic exact-only behavior.
    """

    max_paths: int | None = 16
    depth_slack: int = 0
    max_candidates: int = 8
    orders_per_path: int = 3
    warmup: int = 1
    repeats: int = 3
    prune_ratio: float = 2.0
    synth_density: float = 0.05   # for synthesized measurement tensors
    synth_seed: int = 0
    backends: tuple[str, ...] | None = None
    mesh: Mapping | None = None
    blocks: tuple[int, ...] | None = None
    profile_bucket: str | None = None    # e.g. "log2" (serving streams)
    bucket_tolerance: float = 4.0        # replan when est. cost drifts past


def default_backends() -> tuple[str, ...]:
    """Engine axis default: measure a Pallas engine only where it can
    actually win (compiled kernels on its own device kind — ``pallas``
    on TPU, ``pallas-gpu`` on GPU); everywhere else the XLA engine is
    the honest wall-clock baseline and interpret-mode Pallas is
    validation-only.  The device kind is part of the cache key, so a
    TPU-tuned and a GPU-tuned winner never collide."""
    import jax
    kind = jax.default_backend()
    if kind == "tpu":
        return ("xla", "pallas")
    if kind == "gpu":
        return ("xla", "pallas-gpu")
    return ("xla",)


@dataclasses.dataclass
class SearchStats:
    """What one ``tune`` call actually did (assertable by tests/benchmarks).

    ``executions`` counts every measured kernel launch, warmup included —
    a cache hit performs none.
    """

    cache_hit: bool = False
    cache_key: str = ""
    bucket_hit: bool = False      # served from a bucketed entry (§9 guard ok)
    bucket_key: str = ""          # bucketed key consulted ("" = bucketing off)
    bucket_est_flops: float | None = None   # reused plan's cost on the true
                                            # profile (guard's left-hand side)
    candidates_generated: int = 0
    candidates_timed: int = 0
    executions: int = 0
    pruned: int = 0
    vetoed: int = 0               # rejected by verify_plan pre-measurement
                                  # (E-severity diagnostics; DESIGN.md §11)
    search_seconds: float = 0.0
    best_seconds: float | None = None
    model_seconds: float | None = None   # measured time of the model's pick


def _bucket_reuse_ok(plan, spec: SpTTNSpec, true_levels: Mapping[int, int],
                     config: TunerConfig, stats: "SearchStats") -> bool:
    """Cost-model guard for bucketed reuse (DESIGN.md §9).

    A bucketed entry was tuned for *some* same-bucket profile, not this
    one.  Reuse is safe only while the plan's sparse-aware FLOP estimate
    on the true profile stays within ``bucket_tolerance`` × the estimate
    it was tuned at (``plan.flops``) — log2 buckets bound per-level drift
    by 2x, so a sound entry passes any tolerance ≥ 2; a stale or foreign
    entry whose profile diverged (e.g. the bucketing scheme coarsened)
    fails and forces a replan instead of silently executing a bad nest.
    """
    from repro.core.cost import path_flops
    est_true = path_flops(plan.path, spec.dims, spec.sparse_indices,
                          dict(true_levels))
    stats.bucket_est_flops = est_true
    return est_true <= config.bucket_tolerance * max(plan.flops, 1.0)


def tune(spec: SpTTNSpec,
         cost: TreeCost | None = None,
         nnz_levels: Mapping[int, int] | None = None,
         csf=None,
         factors: Mapping | None = None,
         cache_dir: str | None = None,
         config: TunerConfig | None = None,
         *,
         tuner: TunerConfig | None = None,
         memory_budget: int | None = None):
    """Find the empirically fastest loop nest; returns (plan, stats).

    ``csf``/``factors`` supply measurement inputs; either may be omitted
    and is then synthesized deterministically from the spec.  With
    ``cache_dir`` set, a prior winner for the same (spec, nnz profile,
    device, backend axis, mesh context) is returned without executing any
    candidate.  ``tuner`` is the blessed spelling of the TunerConfig
    kwarg (matching ``plan(tuner=...)``); ``config=`` is a deprecated
    alias.  ``memory_budget`` (bytes) stamps the returned plan with the
    slicing decision of DESIGN.md §10; the budget never enters the cache
    key and the cache stores the unsliced winner, so budgeted and
    unbudgeted callers share one entry.

    >>> from repro.core import spec as S
    >>> tuned, stats = tune(S.mttkrp(8, 6, 5, 4),
    ...                     tuner=TunerConfig(max_paths=2, max_candidates=2,
    ...                                       orders_per_path=1, repeats=2))
    >>> stats.cache_hit
    False
    >>> stats.candidates_timed >= 1
    True
    >>> tuned.backend in ("xla", "pallas", "pallas-gpu")
    True
    """
    from repro.core.planner import _resolve_tuner_alias
    config = _resolve_tuner_alias(tuner, config, "tune") or TunerConfig()
    cost = cost or ConstrainedBlas(bound=2)
    stats = SearchStats()
    t_start = time.perf_counter()

    if csf is None:
        csf, synth = synth_inputs(spec, density=config.synth_density,
                                  seed=config.synth_seed)
        factors = factors if factors is not None else synth
    elif factors is None:
        factors = synth_factors(spec, seed=config.synth_seed)
    levels = dict(nnz_levels) if nnz_levels else (
        csf.nnz_levels() if hasattr(csf, "nnz_levels")
        else default_nnz_levels(spec))

    backends = config.backends or default_backends()
    cache = PlanCache(cache_dir) if cache_dir else None
    device = device_kind()
    key = cache_key(spec, levels, device, backends=backends,
                    mesh=config.mesh, blocks=config.blocks)
    stats.cache_key = key
    bkey = None
    if config.profile_bucket is not None:
        bkey = bucketed_cache_key(spec, levels, device, backends=backends,
                                  mesh=config.mesh, blocks=config.blocks,
                                  scheme=config.profile_bucket)
        stats.bucket_key = bkey
    def _budgeted(p):
        # the slice decision is derived per call from (plan, profile,
        # budget) — never part of the cached schedule (DESIGN.md §10)
        if memory_budget is None:
            return p
        from repro.core.slicing import stamp_plan_slicing
        return stamp_plan_slicing(p, levels, memory_budget)

    if cache is not None:
        hit = cache.get(key)         # exact-key fast path
        if hit is not None:
            stats.cache_hit = True
            stats.search_seconds = time.perf_counter() - t_start
            return _budgeted(hit), stats
        if bkey is not None:
            hit = cache.get(bkey)
            if hit is not None and _bucket_reuse_ok(hit, spec, levels,
                                                    config, stats):
                stats.cache_hit = True
                stats.bucket_hit = True
                stats.search_seconds = time.perf_counter() - t_start
                return _budgeted(hit), stats

    # --- model-side pruning ------------------------------------------- #
    # generate_candidates ranks by TreeCost.evaluate (the ground-truth
    # scale Algorithm 1 optimizes, dense-term offset included), so the
    # ranking head IS the pure-model pick — it is always measured, which
    # guarantees tuned-runtime <= model-runtime on these measurements.
    candidates = generate_candidates(
        spec, cost=cost, nnz_levels=levels, max_paths=config.max_paths,
        depth_slack=config.depth_slack,
        max_candidates=config.max_candidates,
        orders_per_path=config.orders_per_path,
        backends=backends, blocks=config.blocks)
    stats.candidates_generated = len(candidates)

    # --- static verification gate ------------------------------------- #
    # an E-severity diagnostic means some engine would reject (or
    # miscompute) the schedule — never spend compile+measure time on it.
    # Today's generator emits only legal candidates, so this prunes
    # nothing; it is the contract future candidate sources inherit.
    from repro.analysis import verify_plan
    legal = [c for c in candidates
             if verify_plan(spec, c.path, c.order, backend=c.backend,
                            fused=c.fused, block=c.block or None).ok]
    stats.vetoed = len(candidates) - len(legal)
    if not legal:
        raise ValueError(
            "every generated candidate was rejected by verify_plan — "
            "the spec admits no legal schedule on the requested axes")
    candidates = legal
    model_cand = candidates[0]

    # --- empirical measurement ---------------------------------------- #
    from repro.core.executor import CSFArrays
    arrays = (csf if isinstance(csf, CSFArrays)
              else CSFArrays.from_csf(csf))
    mcfg = MeasureConfig(warmup=config.warmup, repeats=config.repeats,
                         prune_ratio=config.prune_ratio)
    results = measure_candidates(spec, candidates, arrays, factors,
                                 config=mcfg, stats=stats)
    # winner selection skips pruned entries explicitly: a pruned
    # measurement is one first-call sample, not a median, and must never
    # win (measure_candidates sorts them last, but the skip is the
    # guarantee, not the sort).  All-pruned can only happen with a
    # degenerate prune_ratio; fall back to the least-bad sample then.
    best = next((m for m in results if not m.pruned), results[0])
    stats.best_seconds = best.seconds
    model_key = model_cand.key
    for m in results:
        if m.candidate.key == model_key:
            stats.model_seconds = m.seconds
            break

    from repro.core.paths import path_depth
    from repro.core.planner import SpTTNPlan
    plan = SpTTNPlan(spec=spec, path=best.candidate.path,
                     order=best.candidate.order, cost=best.candidate.cost,
                     flops=best.candidate.flops,
                     depth=path_depth(best.candidate.path),
                     backend=best.candidate.backend,
                     mesh=None if config.mesh is None else dict(config.mesh),
                     fused=best.candidate.fused,
                     block=(best.candidate.block or None)
                     if best.candidate.backend in PALLAS_BACKENDS else None)

    if cache is not None:
        meta = {
            "best_seconds": best.seconds,
            "model_seconds": stats.model_seconds,
            "candidates_timed": stats.candidates_timed,
            "executions": stats.executions,
            "device": device,
            "backends": list(backends),
            "mesh": None if config.mesh is None else dict(config.mesh),
            "timings": [
                {"seconds": m.seconds, "pruned": m.pruned,
                 "cost": m.candidate.cost, "flops": m.candidate.flops,
                 "backend": m.candidate.backend,
                 "fused": m.candidate.fused,
                 "block": m.candidate.block}
                for m in results],
        }
        cache.put(key, plan, meta=meta)
        if bkey is not None:
            # the serving-stream entry: last same-bucket winner serves the
            # whole bucket (guarded on read, so "last" is safe)
            cache.put(bkey, plan, meta=dict(
                meta, profile_bucket=config.profile_bucket,
                nnz_levels={str(k): int(v) for k, v in sorted(
                    bucket_nnz_levels(levels,
                                      config.profile_bucket).items())}))

    stats.search_seconds = time.perf_counter() - t_start
    return _budgeted(plan), stats
