"""SpTTN planner (paper §5): pick the minimum-cost fully-fused loop nest.

Pipeline:  enumerate min-depth contraction paths  →  Algorithm 1 per path
(under the chosen tree-separable cost)  →  tie-break across paths by the
sparse-aware FLOP model  →  an executable :class:`SpTTNPlan`.

Plans are cached by (spec signature, nnz-level profile), mirroring the
paper's observation that the schedule depends only on the fixed sparsity
pattern, not on values.
"""
from __future__ import annotations

import dataclasses
import functools
from collections.abc import Mapping, Sequence

from repro.core import cost as cost_lib
from repro.core.cost import ConstrainedBlas, TreeCost, path_flops
from repro.core.loopnest import LoopOrder
from repro.core.order_dp import OrderDP
from repro.core.paths import ContractionPath, min_depth_paths, path_depth
from repro.core.spec import SpTTNSpec


@dataclasses.dataclass
class SpTTNPlan:
    """A chosen schedule: contraction path + loop order (+ diagnostics).

    ``backend`` names the execution engine the schedule was selected for
    (``repro.core.executor.BACKENDS``); the autotuner treats it as a search
    axis, so a persisted plan replays on the engine it actually won on.
    ``mesh`` records the distributed shard context the plan was tuned
    under (mesh shape + partitioned axes + shard; ``None`` for a
    single-device plan) and is persisted in plan JSON v3 — see DESIGN.md
    §7.  ``fused`` records whether the schedule won with the Pallas
    backend's single-kernel chain lowering (DESIGN.md §6) — an
    autotuning axis since plan JSON v4; it is False for non-Pallas
    backends.  ``block`` records the Pallas fiber block size the
    schedule won with (DESIGN.md §8) — an autotuning axis since plan
    JSON v5; ``None`` (non-Pallas backends, or a pre-sweep plan) means
    the engine default.  ``slice_mode``/``slice_chunks`` record the
    memory-budget slicing decision (DESIGN.md §10, plan JSON v6): the
    dense mode split into chunks so each replay pass fits the budget the
    plan was stamped under — ``None``/1 means unsliced (fits, or never
    budgeted).  The decision is derived, not tuned: it never enters the
    plan-cache key, and the cache stores the unsliced schedule.
    ``stats`` is attached by autotuned planning (search/cache
    accounting); it is excluded from equality so a cache round trip
    compares identical.
    """

    spec: SpTTNSpec
    path: ContractionPath
    order: LoopOrder
    cost: float
    flops: float
    depth: int
    backend: str = "xla"
    mesh: Mapping | None = None
    fused: bool = False
    block: int | None = None
    slice_mode: str | None = None
    slice_chunks: int = 1
    stats: object | None = dataclasses.field(default=None, compare=False,
                                             repr=False)

    def describe(self) -> str:  # pragma: no cover - debugging aid
        lines = [f"SpTTNPlan depth={self.depth} cost={self.cost} "
                 f"flops={self.flops:.3g} backend={self.backend}"]
        for t, a in zip(self.path, self.order):
            lines.append(f"  {t}   order={','.join(a)}")
        return "\n".join(lines)


def _resolve_tuner_alias(tuner, config, caller: str):
    """``tuner=`` is the blessed spelling of the TunerConfig kwarg across
    the API (``plan``/``tune``); ``config=`` is the deprecated alias."""
    if tuner is not None and config is not None:
        raise ValueError(f"{caller}() got both tuner= and config= "
                         "(aliases for the same TunerConfig); pass tuner=")
    if config is not None:
        import warnings
        warnings.warn(f"{caller}(config=...) is deprecated; use "
                      f"{caller}(tuner=...)", DeprecationWarning,
                      stacklevel=3)
        return config
    return tuner


def plan(spec: SpTTNSpec,
         cost: TreeCost | None = None,
         nnz_levels: Mapping[int, int] | None = None,
         max_paths: int | None = 64,
         depth_slack: int = 0,
         autotune: bool = False,
         cache_dir: str | None = None,
         csf=None,
         factors: Mapping | None = None,
         tuner=None,
         *,
         config=None,
         memory_budget: int | None = None) -> SpTTNPlan:
    """Find the minimum-cost loop nest for an SpTTN kernel.

    Default cost is the paper's experiment metric (§7): maximize BLAS-able
    innermost dense loops with intermediate buffer dimension bounded by 2.

    ``autotune=True`` augments the model with empirical measurement
    (paper §4.1): candidates are model-pruned, compiled, and timed, and the
    winner is persisted under ``cache_dir`` keyed by (spec signature, CSF
    nnz-level profile, device kind) — a later call in any process with the
    same key returns the cached plan without executing a single candidate
    (see ``plan.stats``).  ``csf``/``factors`` supply measurement inputs
    and default to deterministic synthetic ones; ``tuner`` is an optional
    :class:`repro.autotune.TunerConfig` (``config=`` is a deprecated
    alias).

    ``memory_budget`` (bytes) stamps the returned plan with the slicing
    decision that keeps each execution pass within budget
    (``slice_mode``/``slice_chunks``, DESIGN.md §10); ``execute_plan``
    then replays it sliced.  The budget never changes which schedule is
    chosen or cached — only how the winner is replayed.

    >>> from repro.core import spec as S
    >>> p = plan(S.mttkrp(8, 6, 5, 4))
    >>> p.depth
    4
    >>> p.backend
    'xla'
    >>> p.mesh is None       # single-device plan; see DESIGN.md §7
    True
    >>> len(p.path)          # two contraction terms: leaf and root
    2
    """
    tuner = _resolve_tuner_alias(tuner, config, "plan")
    if autotune:
        from repro.autotune import TunerConfig, tune
        if tuner is None:
            # honor this function's search-width arguments; an explicit
            # TunerConfig overrides them wholesale
            tuner = TunerConfig(max_paths=max_paths,
                                depth_slack=depth_slack)
        best, stats = tune(spec, cost=cost, nnz_levels=nnz_levels, csf=csf,
                           factors=factors, cache_dir=cache_dir,
                           tuner=tuner, memory_budget=memory_budget)
        best.stats = stats
        return best
    cost = cost or ConstrainedBlas(bound=2)
    if nnz_levels is None:
        # density-agnostic default: nnz^(I1..Ip) grows with the prefix space
        sp = spec.sparse_indices
        prod = 1
        nnz_levels = {0: 1}
        for p, ind in enumerate(sp, start=1):
            prod *= spec.dims[ind]
            nnz_levels[p] = prod

    def search(cost, max_paths):
        best: SpTTNPlan | None = None
        for path in min_depth_paths(spec, max_paths=max_paths,
                                    slack=depth_slack):
            dp = OrderDP(path, cost, spec.dims, spec.sparse_indices)
            res = dp.solve()
            if res.order is None or res.cost == cost_lib.INF:
                continue
            c = res.cost
            if isinstance(cost, ConstrainedBlas):
                c += cost.order_independent_offset(path, spec.sparse_indices)
            f = path_flops(path, spec.dims, spec.sparse_indices, nnz_levels)
            cand = SpTTNPlan(spec=spec, path=path, order=res.order, cost=c,
                             flops=f, depth=path_depth(path))
            if best is None or (cand.cost, cand.flops) < (best.cost,
                                                          best.flops):
                best = cand
        return best

    best = search(cost, max_paths)
    if best is None and max_paths is not None:
        # constraint infeasible within the path cap: widen the search
        best = search(cost, None)
    if best is None and isinstance(cost, ConstrainedBlas):
        # every path violates the buffer bound: fall back to minimizing
        # buffer size outright (always feasible)
        from repro.core.cost import MaxBufferSize
        best = search(MaxBufferSize(), max_paths)
    if best is None:
        raise ValueError(f"no feasible loop nest found for {spec}")
    if memory_budget is not None:
        from repro.core.slicing import stamp_plan_slicing
        best = stamp_plan_slicing(best, nnz_levels, memory_budget)
    return best


@functools.lru_cache(maxsize=256)
def _cached_plan_key(expr: str, dims_key: tuple, sparse: int | None,
                     nnz_key: tuple, bound: int) -> SpTTNPlan:
    from repro.core.spec import parse
    spec = parse(expr, dims=dict(dims_key), sparse=sparse)
    return plan(spec, cost=ConstrainedBlas(bound=bound),
                nnz_levels=dict(nnz_key) if nnz_key else None)


def cached_plan(expr: str, dims: Mapping[str, int], sparse: int | None = 0,
                nnz_levels: Mapping[int, int] | None = None,
                bound: int = 2) -> SpTTNPlan:
    """LRU-cached planning keyed by the kernel signature (pattern-static)."""
    return _cached_plan_key(expr, tuple(sorted(dims.items())), sparse,
                            tuple(sorted((nnz_levels or {}).items())), bound)


def autotune(spec: SpTTNSpec, csf, factors,
             candidates: Sequence[tuple[ContractionPath, LoopOrder]],
             repeats: int = 3):
    """Measurement-driven selection among explicit (path, order) pairs
    (§4's 'enumeration enables autotuning').  Thin wrapper over
    :mod:`repro.autotune` for callers that bring their own candidate list;
    returns (best_candidate, [(seconds, path, order), ...] ascending).
    """
    from repro.autotune.candidates import Candidate
    from repro.autotune.measure import MeasureConfig, measure_candidates
    from repro.core.executor import CSFArrays

    arrays = csf if isinstance(csf, CSFArrays) else CSFArrays.from_csf(csf)
    cands = [Candidate(path=p, order=o, cost=0.0, flops=0.0)
             for p, o in candidates]
    ms = measure_candidates(
        spec, cands, arrays, factors,
        config=MeasureConfig(warmup=1, repeats=repeats, prune_ratio=0.0))
    results = [(m.seconds, m.candidate.path, m.candidate.order) for m in ms]
    _, path, order = results[0]
    return (path, order), results
