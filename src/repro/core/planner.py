"""SpTTN planner (paper §5): pick the minimum-cost fully-fused loop nest.

Pipeline:  enumerate min-depth contraction paths  →  Algorithm 1 per path
(under the chosen tree-separable cost)  →  tie-break across paths by the
sparse-aware FLOP model  →  an executable :class:`SpTTNPlan`.

Plans are cached by (spec signature, nnz-level profile), mirroring the
paper's observation that the schedule depends only on the fixed sparsity
pattern, not on values.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Sequence

from repro.core import cost as cost_lib
from repro.core.cost import ConstrainedBlas, TreeCost, path_flops
from repro.core.loopnest import LoopOrder
from repro.core.order_dp import OrderDP
from repro.core.paths import ContractionPath, min_depth_paths, path_depth
from repro.core.spec import SpTTNSpec


@dataclasses.dataclass
class SpTTNPlan:
    """A chosen schedule: contraction path + loop order (+ diagnostics)."""

    spec: SpTTNSpec
    path: ContractionPath
    order: LoopOrder
    cost: float
    flops: float
    depth: int

    def describe(self) -> str:  # pragma: no cover - debugging aid
        lines = [f"SpTTNPlan depth={self.depth} cost={self.cost} "
                 f"flops={self.flops:.3g}"]
        for t, a in zip(self.path, self.order):
            lines.append(f"  {t}   order={','.join(a)}")
        return "\n".join(lines)


def plan(spec: SpTTNSpec,
         cost: TreeCost | None = None,
         nnz_levels: Mapping[int, int] | None = None,
         max_paths: int | None = 64,
         depth_slack: int = 0) -> SpTTNPlan:
    """Find the minimum-cost loop nest for an SpTTN kernel.

    Default cost is the paper's experiment metric (§7): maximize BLAS-able
    innermost dense loops with intermediate buffer dimension bounded by 2.
    """
    cost = cost or ConstrainedBlas(bound=2)
    if nnz_levels is None:
        # density-agnostic default: nnz^(I1..Ip) grows with the prefix space
        sp = spec.sparse_indices
        prod = 1
        nnz_levels = {0: 1}
        for p, ind in enumerate(sp, start=1):
            prod *= spec.dims[ind]
            nnz_levels[p] = prod

    def search(cost, max_paths):
        best: SpTTNPlan | None = None
        for path in min_depth_paths(spec, max_paths=max_paths,
                                    slack=depth_slack):
            dp = OrderDP(path, cost, spec.dims, spec.sparse_indices)
            res = dp.solve()
            if res.order is None or res.cost == cost_lib.INF:
                continue
            c = res.cost
            if isinstance(cost, ConstrainedBlas):
                c += cost.order_independent_offset(path, spec.sparse_indices)
            f = path_flops(path, spec.dims, spec.sparse_indices, nnz_levels)
            cand = SpTTNPlan(spec=spec, path=path, order=res.order, cost=c,
                             flops=f, depth=path_depth(path))
            if best is None or (cand.cost, cand.flops) < (best.cost,
                                                          best.flops):
                best = cand
        return best

    best = search(cost, max_paths)
    if best is None and max_paths is not None:
        # constraint infeasible within the path cap: widen the search
        best = search(cost, None)
    if best is None and isinstance(cost, ConstrainedBlas):
        # every path violates the buffer bound: fall back to minimizing
        # buffer size outright (always feasible)
        from repro.core.cost import MaxBufferSize
        best = search(MaxBufferSize(), max_paths)
    if best is None:
        raise ValueError(f"no feasible loop nest found for {spec}")
    return best


@functools.lru_cache(maxsize=256)
def _cached_plan_key(expr: str, dims_key: tuple, sparse: int | None,
                     nnz_key: tuple, bound: int) -> SpTTNPlan:
    from repro.core.spec import parse
    spec = parse(expr, dims=dict(dims_key), sparse=sparse)
    return plan(spec, cost=ConstrainedBlas(bound=bound),
                nnz_levels=dict(nnz_key) if nnz_key else None)


def cached_plan(expr: str, dims: Mapping[str, int], sparse: int | None = 0,
                nnz_levels: Mapping[int, int] | None = None,
                bound: int = 2) -> SpTTNPlan:
    """LRU-cached planning keyed by the kernel signature (pattern-static)."""
    return _cached_plan_key(expr, tuple(sorted(dims.items())), sparse,
                            tuple(sorted((nnz_levels or {}).items())), bound)


def autotune(spec: SpTTNSpec, csf, factors,
             candidates: Sequence[tuple[ContractionPath, LoopOrder]],
             repeats: int = 3):
    """Measurement-driven selection among enumerated loop nests (§4's
    'enumeration enables autotuning').  Executes each candidate with the
    vectorized engine and returns (best_candidate, timings)."""
    import time

    import jax

    from repro.core.executor import CSFArrays, VectorizedExecutor

    arrays = CSFArrays.from_csf(csf) if not hasattr(csf, "values_") else csf
    results = []
    for path, order in candidates:
        ex = VectorizedExecutor(spec, path, order)
        fn = jax.jit(lambda f, e=ex: e(arrays, f))
        out = fn(factors)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(factors)
        jax.block_until_ready(out)
        results.append(((time.perf_counter() - t0) / repeats, path, order))
    results.sort(key=lambda r: r[0])
    t, path, order = results[0]
    return (path, order), results
