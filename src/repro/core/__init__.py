"""Core SpTTN machinery: the paper's primary contribution.

Public API:
  spec.parse / spec.mttkrp / ...      SpTTN kernel specs
  paths.min_depth_paths                contraction-path enumeration (§4.1.1)
  loopnest.enumerate_orders            index-order enumeration (§4.1.2)
  enumerate.enumerate_loop_nests       exhaustive (path, order) space (§4.1)
  cost.{MaxBufferDim,MaxBufferSize,CacheMisses,ConstrainedBlas}   (§4.2)
  order_dp.optimal_order               Algorithm 1
  planner.plan / cached_plan           full pipeline (§5)
  executor.{reference_execute,VectorizedExecutor,make_executor}   (Alg. 2;
    the three engines of DESIGN.md §3/§6 behind one signature)
"""
from repro.core import cost, executor, loopnest, order_dp, paths
from repro.core import planner, spec
from repro.core.cost import (CacheMisses, ConstrainedBlas, MaxBufferDim,
                             MaxBufferSize)
from repro.core.enumerate import brute_force_optimal, enumerate_loop_nests
from repro.core.executor import (BACKENDS, CSFArrays, ReferenceExecutor,
                                 VectorizedExecutor, dense_oracle,
                                 execute_plan, execute_unfactorized,
                                 make_executor, reference_execute)
from repro.core.order_dp import optimal_order
from repro.core.planner import SpTTNPlan, cached_plan, plan
from repro.core.spec import SpTTNSpec, parse

__all__ = [
    "cost", "executor", "loopnest", "order_dp", "paths",
    "planner", "spec", "CacheMisses", "ConstrainedBlas", "MaxBufferDim",
    "MaxBufferSize", "BACKENDS", "CSFArrays", "ReferenceExecutor",
    "VectorizedExecutor", "dense_oracle", "execute_plan",
    "execute_unfactorized", "make_executor", "reference_execute",
    "brute_force_optimal", "enumerate_loop_nests", "optimal_order",
    "SpTTNPlan", "cached_plan", "plan", "SpTTNSpec", "parse",
]
