"""SpTTN kernel specification.

An SpTTN kernel (paper §3) is a contraction of a single sparse tensor with a
network of dense tensors, whose output is dense or shares the sparse tensor's
sparsity pattern exactly.  We describe kernels with an einsum-like string,
e.g. MTTKRP is ``"ijk,ja,ka->ia"`` with input 0 sparse.

Indices are single characters.  Dimension sizes are supplied separately.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class TensorRef:
    """A tensor operand: a name and an ordered index tuple."""

    name: str
    indices: tuple[str, ...]
    is_sparse: bool = False

    def __post_init__(self):
        if len(set(self.indices)) != len(self.indices):
            raise ValueError(
                f"repeated index within one tensor is unsupported: {self}")

    @property
    def order(self) -> int:
        return len(self.indices)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        star = "*" if self.is_sparse else ""
        return f"{self.name}{star}({','.join(self.indices)})"


@dataclasses.dataclass(frozen=True)
class SpTTNSpec:
    """A full SpTTN kernel: inputs, output, and index dimensions.

    ``inputs[sparse_input]`` is the sparse tensor (or None for an all-dense
    network, which we also support for completeness).  The output either has
    no sparse-only indices (dense output) or exactly the sparse tensor's
    index set (same-sparsity output, e.g. TTTP).

    Build one with :func:`parse` or the named constructors below:

    >>> spec = mttkrp(8, 6, 5, 4)         # "ijk,ja,ka->ia", input 0 sparse
    >>> spec.sparse_indices               # CSF storage order
    ('i', 'j', 'k')
    >>> spec.contracted_indices
    ('j', 'k')
    >>> spec.output_is_sparse
    False
    >>> spec.size("a")
    4
    """

    inputs: tuple[TensorRef, ...]
    output: TensorRef
    dims: Mapping[str, int]

    def __post_init__(self):
        n_sparse = sum(t.is_sparse for t in self.inputs)
        if n_sparse > 1:
            raise ValueError("SpTTN allows at most one sparse input")
        all_inds = set()
        for t in self.inputs:
            all_inds |= set(t.indices)
        missing = set(self.output.indices) - all_inds
        if missing:
            raise ValueError(f"output indices {missing} not found in inputs")
        undimmed = (all_inds | set(self.output.indices)) - set(self.dims)
        if undimmed:
            raise ValueError(f"no dimension given for indices {undimmed}")

    # ------------------------------------------------------------------ #
    @property
    def sparse_input(self) -> TensorRef | None:
        for t in self.inputs:
            if t.is_sparse:
                return t
        return None

    @property
    def sparse_indices(self) -> tuple[str, ...]:
        """Sparse indices in CSF storage order (= sparse tensor index order)."""
        sp = self.sparse_input
        return sp.indices if sp is not None else ()

    @property
    def all_indices(self) -> tuple[str, ...]:
        seen: list[str] = []
        for t in (*self.inputs, self.output):
            for i in t.indices:
                if i not in seen:
                    seen.append(i)
        return tuple(seen)

    @property
    def contracted_indices(self) -> tuple[str, ...]:
        out = set(self.output.indices)
        return tuple(i for i in self.all_indices if i not in out)

    @property
    def output_is_sparse(self) -> bool:
        """True when output has same sparsity as the sparse input (TTTP-like)."""
        sp = self.sparse_input
        return (sp is not None
                and set(self.output.indices) == set(sp.indices))

    def size(self, index: str) -> int:
        return self.dims[index]

    def __str__(self) -> str:  # pragma: no cover
        ins = ",".join(str(t) for t in self.inputs)
        return f"{ins}->{self.output}"


def parse(expr: str,
          dims: Mapping[str, int],
          sparse: int | None = 0,
          names: Sequence[str] | None = None) -> SpTTNSpec:
    """Parse ``"ijk,ja,ka->ia"`` into an :class:`SpTTNSpec`.

    ``sparse`` is the position of the sparse input (None = all dense).

    >>> spec = parse("ijk,ja,ka->ia",
    ...              dims={"i": 8, "j": 6, "k": 5, "a": 4},
    ...              names=["T", "B", "C"])
    >>> str(spec)
    'T*(i,j,k),B(j,a),C(k,a)->OUT(i,a)'
    >>> spec.sparse_input.name
    'T'
    """
    if "->" not in expr:
        raise ValueError("explicit output required, e.g. 'ijk,ja->ia'")
    lhs, rhs = expr.split("->")
    in_specs = lhs.split(",")
    if names is None:
        names = [f"T{i}" for i in range(len(in_specs))]
    inputs = tuple(
        TensorRef(name=names[i], indices=tuple(s), is_sparse=(i == sparse))
        for i, s in enumerate(in_specs))
    output = TensorRef(name="OUT", indices=tuple(rhs))
    return SpTTNSpec(inputs=inputs, output=output, dims=dict(dims))


# Convenience constructors for the paper's kernels (§2.3). ------------------ #

def mttkrp(I: int, J: int, K: int, R: int) -> SpTTNSpec:
    """Eq. 1: A(i,a) = sum_jk T(i,j,k) B(j,a) C(k,a)."""
    return parse("ijk,ja,ka->ia", dims={"i": I, "j": J, "k": K, "a": R},
                 names=["T", "B", "C"])


def ttmc3(I: int, J: int, K: int, R: int, S: int) -> SpTTNSpec:
    """Eq. 2: S(i,r,s) = sum_jk T(i,j,k) U(j,r) V(k,s)."""
    return parse("ijk,jr,ks->irs", dims={"i": I, "j": J, "k": K,
                                         "r": R, "s": S},
                 names=["T", "U", "V"])


def ttmc4(I: int, J: int, K: int, L: int, R: int, S: int, U: int) -> SpTTNSpec:
    """§5.3: S(i,r,s,t) = sum_jkl T(i,j,k,l) U(j,r) V(k,s) W(l,t)."""
    return parse("ijkl,jr,ks,lt->irst",
                 dims={"i": I, "j": J, "k": K, "l": L,
                       "r": R, "s": S, "t": U},
                 names=["T", "U", "V", "W"])


def tttp3(I: int, J: int, K: int, R: int) -> SpTTNSpec:
    """Eq. 3: S(i,j,k) = sum_r T(i,j,k) U(i,r) V(j,r) W(k,r) (SDDMM-like)."""
    return parse("ijk,ir,jr,kr->ijk",
                 dims={"i": I, "j": J, "k": K, "r": R},
                 names=["T", "U", "V", "W"])


def sddmm(I: int, J: int, R: int) -> SpTTNSpec:
    """Order-2 TTTP = SDDMM: S(i,j) = T(i,j) * sum_r U(i,r) V(j,r)."""
    return parse("ij,ir,jr->ij", dims={"i": I, "j": J, "r": R},
                 names=["T", "U", "V"])


def tttc6(N: int, R: int, E: int | None = None) -> SpTTNSpec:
    """Eq. 4 (TTTc): order-6 tensor-train contraction producing Z(e,n).

    Z(e,n) = sum T(i,j,k,l,m,n) A(i,a) B(a,j,b) C(b,k,c) D(c,l,d) E(d,m,e)
    """
    E = E or R
    dims = {c: N for c in "ijklmn"}
    dims.update({c: R for c in "abcd"})
    dims["e"] = E
    return parse("ijklmn,ia,ajb,bkc,cld,dme->en", dims=dims,
                 names=["T", "A", "B", "C", "D", "E"])
