"""Exhaustive loop-nest enumeration (paper §4.1) — the autotuning space.

The size is O((n!)^2/(n·2^n) · prod |I_i|!/k_i!); use only for small kernels
(every paper kernel is small: n <= 6, m <= 10) or for property tests.
"""
from __future__ import annotations

from collections.abc import Iterator, Mapping, Sequence

from repro.core.cost import TreeCost
from repro.core.loopnest import LoopOrder, enumerate_orders
from repro.core.paths import ContractionPath, min_depth_paths
from repro.core.spec import SpTTNSpec

__all__ = ["SpTTNSpec", "brute_force_optimal", "enumerate_loop_nests"]


def enumerate_loop_nests(spec: SpTTNSpec,
                         max_paths: int | None = None,
                         depth_slack: int = 0
                         ) -> Iterator[tuple[ContractionPath, LoopOrder]]:
    """Yield (contraction path, loop order) pairs spanning the search space."""
    for path in min_depth_paths(spec, max_paths=max_paths, slack=depth_slack):
        for order in enumerate_orders(path, spec.sparse_indices):
            yield path, order


def brute_force_optimal(path: ContractionPath, cost: TreeCost,
                        dims: Mapping[str, int],
                        sparse_storage: Sequence[str] = ()
                        ) -> tuple[LoopOrder, float]:
    """Ground-truth optimum by evaluating every valid loop order.

    Used by property tests to validate Algorithm 1 (Theorem 4.9).
    """
    best: tuple[LoopOrder, float] | None = None
    for order in enumerate_orders(path, sparse_storage):
        c = cost.evaluate(path, order, dims, sparse_storage)
        if best is None or c < best[1]:
            best = (order, c)
    if best is None:
        raise ValueError("no valid order")
    return best
