"""Tree-separable cost functions (paper §4.2.2-4.2.4, Defs 4.6-4.8).

Each cost provides:
  * the DP interface used by Algorithm 1 — an identity element ``zero``, an
    associative nondecreasing ``combine`` (the paper's ``⊕``), and ``phi``
    (the paper's ``φ_{T,L,r}``) evaluated at a peel of root ``q`` splitting
    the current term subsequence;
  * ``evaluate`` — an *independent* ground-truth evaluation on the fused
    forest, used to property-test the DP against exhaustive enumeration.

Cost instances implemented:
  * :class:`MaxBufferDim` / :class:`MaxBufferSize` (Def 4.7),
  * :class:`CacheMisses`  (Def 4.8),
  * :class:`ConstrainedBlas` — the metric used in the paper's experiments
    (§5/§7): maximize the number of innermost independent dense (BLAS-able)
    loops subject to a bound on intermediate buffer dimension.

Every cost scores the same object — a contraction path plus a loop
order — on the MTTKRP running example (docs/cost-models.md walks
through these numbers):

>>> from repro.core import spec as S
>>> from repro.core.cost import (CacheMisses, ConstrainedBlas,
...                              MaxBufferDim, MaxBufferSize)
>>> from repro.core.order_dp import optimal_order
>>> from repro.core.planner import plan
>>> spec = S.mttkrp(8, 6, 5, 4)   # A(i,a) = sum_jk T(i,j,k) B(j,a) C(k,a)
>>> path = plan(spec).path        # leaf term T.C, then root term B.(T.C)
>>> [str(t) for t in path]
['T*(i,j,k) . C(k,a) -> (T.C)*(i,j,a)', 'B(j,a) . (T.C)*(i,j,a) -> OUT(i,a)']

The fully fused nest keeps the crossing buffer scalar (one element), so
the Def-4.7 optima are tiny:

>>> order, best = optimal_order(path, MaxBufferSize(), spec.dims,
...                             spec.sparse_indices)
>>> order
(('i', 'j', 'a', 'k'), ('i', 'j', 'a'))
>>> best
1
>>> MaxBufferDim().evaluate(path, order, spec.dims, spec.sparse_indices)
0

The paper's experiment metric trades that for MXU-offloadable loops: the
best order ends both terms in the dense index ``a`` (two BLAS-able
loops, hence cost −2 under minimization), at a buffer dimension still
within the bound:

>>> order, best = optimal_order(path, ConstrainedBlas(bound=2), spec.dims,
...                             spec.sparse_indices)
>>> (order, best)
((('i', 'j', 'k', 'a'), ('i', 'j', 'a')), -2.0)
>>> optimal_order(path, CacheMisses(), spec.dims, spec.sparse_indices)[1]
272.0
"""
from __future__ import annotations

import abc
import dataclasses
import math
from collections.abc import Mapping, Sequence

from repro.core.loopnest import (Forest, LoopOrder, TermLeaf,
                                 build_forest)
from repro.core.paths import ContractionPath, Term, consumer_map

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class PhiCtx:
    """Context for one φ application: peel of root ``q`` over terms X.

    ``crossing_out``: for every buffer edge whose producer lies in X and
    whose consumer lies in the Y side of this peel, the producer's remaining
    output indices (``K_3`` of Def 4.7, with already-iterated indices
    removed; ``q`` itself NOT removed — the buffer carries the ``q`` dim).
    ``terms_x``: the (global_id, Term) pairs placed under loop ``q``.
    ``removed``: indices iterated above this peel (excludes ``q``).
    """

    q: str
    removed: frozenset[str]
    terms_x: tuple[tuple[int, Term], ...]
    crossing_out: tuple[tuple[str, ...], ...]
    dims: Mapping[str, int]
    sparse: frozenset[str]


class TreeCost(abc.ABC):
    """A tree-separable cost function (Def 4.6)."""

    zero: float = 0.0

    def scalar_buffer(self) -> float:
        """Contribution of a fully-fused scalar intermediate (a buffer whose
        producer exhausts inside the consumer's loop group, so no peel ever
        separates the edge).  Size-type costs count 1 element; dim/cache
        costs count 0."""
        return 0.0

    @abc.abstractmethod
    def combine(self, a: float, b: float) -> float:
        """The associative, nondecreasing ``⊕``."""

    @abc.abstractmethod
    def phi(self, ctx: PhiCtx, inner: float) -> float:
        """``φ_{T,L,q}`` applied to the combined cost of the children."""

    @abc.abstractmethod
    def evaluate(self, path: ContractionPath, order: LoopOrder,
                 dims: Mapping[str, int],
                 sparse: Sequence[str]) -> float:
        """Independent ground-truth evaluation on the fused forest."""


# --------------------------------------------------------------------------- #
# helpers shared by ground-truth evaluators
# --------------------------------------------------------------------------- #
def _forest_edges(path: ContractionPath, order: LoopOrder):
    """(forest, per-edge (producer, consumer, buffer-remaining-inds)).
    Ancestors are vertex-identity LCAs (same-label loops separated by a
    sibling are distinct vertices — their iterations are not shared)."""
    from repro.core.loopnest import (common_ancestor_indices,
                                     leaf_vertex_paths)
    forest = build_forest(order)
    paths_ = leaf_vertex_paths(forest)
    cons = consumer_map(path)
    edges = []
    for u, v in cons.items():
        anc = common_ancestor_indices(paths_[u], paths_[v])
        rem = tuple(i for i in path[u].out.indices if i not in anc)
        edges.append((u, v, rem))
    return forest, edges


# --------------------------------------------------------------------------- #
# Def 4.7 — maximum buffer dimension / size
# --------------------------------------------------------------------------- #
class MaxBufferDim(TreeCost):
    """φ(x) = max(ρ, x) with ρ = max |K_3| over edges crossing the peel."""

    def combine(self, a, b):
        return max(a, b)

    def phi(self, ctx: PhiCtx, inner):
        rho = max((len(k3) for k3 in ctx.crossing_out), default=0)
        return max(rho, inner)

    def evaluate(self, path, order, dims, sparse):
        _, edges = _forest_edges(path, order)
        return max((len(rem) for _, _, rem in edges), default=0)


class MaxBufferSize(TreeCost):
    """Same as MaxBufferDim with ρ = product of K_3 dims (paper §4.2.3)."""

    def scalar_buffer(self) -> float:
        return 1.0  # a scalar intermediate still occupies one element

    def combine(self, a, b):
        return max(a, b)

    def phi(self, ctx: PhiCtx, inner):
        rho = max((math.prod(ctx.dims[i] for i in k3)
                   for k3 in ctx.crossing_out), default=0)
        return max(rho, inner)

    def evaluate(self, path, order, dims, sparse):
        _, edges = _forest_edges(path, order)
        return max((math.prod(dims[i] for i in rem)
                    for _, _, rem in edges), default=0)


# --------------------------------------------------------------------------- #
# Def 4.8 — cache-miss model
# --------------------------------------------------------------------------- #
class CacheMisses(TreeCost):
    """φ(x) = I(q)·(τ + x); τ counts distinct tensors under the loop that are
    indexed by q and still have more than D indices left to iterate."""

    def __init__(self, D: int = 1):
        self.D = D

    def combine(self, a, b):
        return a + b

    def _tau(self, q: str, removed: frozenset[str],
             terms: Sequence[tuple[int, Term]]) -> int:
        seen: set[str] = set()
        for _, t in terms:
            for op in (t.lhs, t.rhs, t.out):
                rem = [i for i in op.indices if i not in removed]
                if q in rem and len(rem) > self.D and op.name not in seen:
                    seen.add(op.name)
        return len(seen)

    def phi(self, ctx: PhiCtx, inner):
        tau = self._tau(ctx.q, ctx.removed, ctx.terms_x)
        return ctx.dims[ctx.q] * (tau + inner)

    def evaluate(self, path, order, dims, sparse):
        forest = build_forest(order)

        def terms_under(f: Forest) -> list[int]:
            out = []
            for n in f:
                if isinstance(n, TermLeaf):
                    out.append(n.term_id)
                else:
                    out.extend(terms_under(n.children))
            return out

        def rec(f: Forest, removed: frozenset[str]) -> float:
            total = 0.0
            for n in f:
                if isinstance(n, TermLeaf):
                    continue
                tids = terms_under(n.children)
                tau = self._tau(n.index, removed,
                                [(t, path[t]) for t in tids])
                inner = rec(n.children, removed | {n.index})
                total += dims[n.index] * (tau + inner)
            return total

        return rec(forest, frozenset())


# --------------------------------------------------------------------------- #
# Paper §5/§7 experiment metric — max BLAS-able dense loops, bounded buffers
# --------------------------------------------------------------------------- #
class ConstrainedBlas(TreeCost):
    """Minimize ``-(number of innermost independent dense loops)`` subject to
    every intermediate buffer having dimension <= ``bound`` (INF otherwise).

    A term's BLAS-able loops are the *trailing dense* indices of its loop
    order (the contiguous dense suffix offloadable to xAXPY/xGER/GEMM — on
    TPU, a single MXU ``dot_general``).  For a term containing sparse
    indices, the suffix contribution is committed by φ at the peel where the
    term's LAST sparse index is iterated; terms with no sparse indices at
    all contribute |indices| regardless of order and are handled by a
    constant offset (see :meth:`order_independent_offset`).
    """

    zero = 0.0

    def __init__(self, bound: int = 2):
        self.bound = bound

    def combine(self, a, b):
        return a + b

    def phi(self, ctx: PhiCtx, inner):
        if any(len(k3) > self.bound for k3 in ctx.crossing_out):
            return INF
        credit = 0
        if ctx.q in ctx.sparse:
            for _, t in ctx.terms_x:
                rem = [i for i in t.indices if i not in ctx.removed]
                sp_rem = [i for i in rem if i in ctx.sparse]
                if sp_rem == [ctx.q]:  # q is the term's last sparse index
                    credit += sum(1 for i in rem if i not in ctx.sparse)
        return inner - credit

    def order_independent_offset(self, path: ContractionPath,
                                 sparse: Sequence[str]) -> float:
        sp = set(sparse)
        off = 0
        for t in path:
            if not any(i in sp for i in t.indices):
                off -= len(t.indices)
        return float(off)

    def evaluate(self, path, order, dims, sparse):
        sp = set(sparse)
        _, edges = _forest_edges(path, order)
        if any(len(rem) > self.bound for _, _, rem in edges):
            return INF
        total = 0
        for a in order:
            n = 0
            for i in reversed(a):
                if i in sp:
                    break
                n += 1
            total -= n
        return float(total)


# --------------------------------------------------------------------------- #
# FLOP model (order-independent; used by the planner across paths)
# --------------------------------------------------------------------------- #
def path_flops(path: ContractionPath, dims: Mapping[str, int],
               sparse_storage: Sequence[str],
               nnz_levels: Mapping[int, int]) -> float:
    """2 * (#loop-iterations) per term, sparse-aware.

    A term whose sparse indices reach CSF level p iterates nnz^(I1..Ip)
    fibers times the product of its dense dims (paper §2.4's operation
    counts, e.g. pairwise MTTKRP = 2·nnz(T)·A + 2·nnz^(IJ)·A).
    """
    pos = {s: i + 1 for i, s in enumerate(sparse_storage)}
    total = 0.0
    for t in path:
        sp_lvl = max((pos[i] for i in t.indices if i in pos), default=0)
        dense = math.prod(dims[i] for i in t.indices if i not in pos)
        if sp_lvl:
            total += 2.0 * nnz_levels.get(sp_lvl, 0) * dense
        else:
            total += 2.0 * dense
    return total


def buffer_bytes(path: ContractionPath, order: LoopOrder,
                 dims: Mapping[str, int],
                 sparse_storage: Sequence[str],
                 nnz_levels: Mapping[int, int],
                 itemsize: int = 4) -> int:
    """Total bytes of vectorized intermediates (fiber-level materialization).

    This is the TPU-adapted memory model: a buffer fused at sparse depth p
    with dense indices Dset occupies nnz^(I1..Ip) * prod(Dset) elements.
    The memory-budgeted slicing pass (:mod:`repro.core.slicing`,
    DESIGN.md §10) prices chunk candidates by re-evaluating this under
    chunk-restricted ``dims`` — keep it a pure function of its arguments.
    """
    from repro.core.loopnest import buffer_indices, fused_sparse_depth
    pos = {s: i for i, s in enumerate(sparse_storage)}
    binds = buffer_indices(path, order)
    bdepth = fused_sparse_depth(path, order, sparse_storage)
    total = 0
    for u, inds in binds.items():
        dense = math.prod(dims[i] for i in inds if i not in pos)
        sp_in_buf = [i for i in inds if i in pos]
        if sp_in_buf:
            lvl = max(pos[i] for i in sp_in_buf) + 1
            rows = nnz_levels.get(lvl, 0)
        else:
            rows = max(1, nnz_levels.get(bdepth[u], 1)) if bdepth[u] else 1
        total += rows * dense * itemsize
    return total
