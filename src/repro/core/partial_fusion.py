"""Partially-fused loop nests — the paper's §8 future-work direction.

The paper restricts search to FULLY-fused forests ("no vertex has two
consecutive children with the same index") and notes that partial fusion
"would be particularly useful for cost metrics like number of BLAS kernels
or the degree of parallelism".  We extend the search space with *fusion
barriers*: a barrier between consecutive terms t and t+1 forbids merging
their loops even where prefixes match, trading buffer size for

  * larger independent dense loop nests (higher BLAS/MXU offload degree) —
    an unfused producer keeps ALL its trailing dense loops contiguous;
  * independent (parallelizable) subtrees.

Enumeration-level feature: costs are evaluated on the barrier-respecting
forest; Algorithm 1 remains the engine for the fully-fused optimum (its
optimal-substructure argument does not carry over once barriers decouple
subproblem roots, so partial fusion is searched by enumeration — exactly
the autotuning mode the paper prescribes for such metrics).
"""
from __future__ import annotations

import itertools
import math
from collections.abc import Iterator, Sequence

from repro.core.loopnest import (Forest, LoopNode, LoopOrder, TermLeaf,
                                 common_ancestor_indices, leaf_vertex_paths)
from repro.core.paths import ContractionPath, consumer_map

Barriers = tuple[bool, ...]  # barriers[t] splits terms t and t+1


def build_forest_with_barriers(order: LoopOrder,
                               barriers: Barriers | None = None) -> Forest:
    """Fused forest construction honoring fusion barriers."""
    n = len(order)
    barriers = barriers or (False,) * max(n - 1, 0)

    def rec(seq) -> Forest:
        forest: Forest = []
        i = 0
        while i < len(seq):
            tid, rem = seq[i]
            if not rem:
                forest.append(TermLeaf(term_id=tid))
                i += 1
                continue
            q = rem[0]
            group = [(tid, rem[1:])]
            j = i + 1
            while (j < len(seq) and seq[j][1] and seq[j][1][0] == q
                   and not barriers[seq[j][0] - 1]):
                group.append((seq[j][0], seq[j][1][1:]))
                j += 1
            forest.append(LoopNode(index=q, children=rec(group)))
            i = j
        return forest

    return rec([(i, a) for i, a in enumerate(order)])


def partial_fusion_metrics(path: ContractionPath, order: LoopOrder,
                           barriers: Barriers,
                           dims, sparse: Sequence[str]) -> dict:
    """(max buffer dim/size, total BLAS-able dense loops, #parallel roots)
    for a barrier choice."""
    forest = build_forest_with_barriers(order, barriers)
    paths_ = leaf_vertex_paths(forest)
    cons = consumer_map(path)
    sp = set(sparse)
    max_dim, max_size = 0, 0
    for u, v in cons.items():
        anc = common_ancestor_indices(paths_[u], paths_[v])
        rem = [i for i in path[u].out.indices if i not in anc]
        max_dim = max(max_dim, len(rem))
        max_size = max(max_size, math.prod(dims[i] for i in rem) if rem
                       else 1)
    # BLAS degree: per leaf, contiguous dense loops directly above it that
    # enclose only this leaf (single-child chain)
    blas = 0
    for tid, vpath in paths_.items():
        # walk from the leaf upward while the loop is dense
        n = 0
        for _, idx in reversed(vpath):
            if idx in sp:
                break
            n += 1
        blas += n
    return {"max_buffer_dim": max_dim, "max_buffer_size": max_size,
            "blas_loops": blas, "n_roots": len(forest)}


def enumerate_barrier_choices(n_terms: int) -> Iterator[Barriers]:
    for combo in itertools.product([False, True], repeat=max(n_terms - 1, 0)):
        yield combo


def best_partial_fusion(path: ContractionPath, order: LoopOrder,
                        dims, sparse: Sequence[str],
                        buffer_dim_bound: int | None = None
                        ) -> tuple[Barriers, dict]:
    """Maximize BLAS-able loops subject to an optional buffer-dim bound —
    the cost the paper names as the one partial fusion serves."""
    best = None
    for b in enumerate_barrier_choices(len(path)):
        m = partial_fusion_metrics(path, order, b, dims, sparse)
        if buffer_dim_bound is not None and \
                m["max_buffer_dim"] > buffer_dim_bound:
            continue
        key = (m["blas_loops"], -m["max_buffer_size"])
        if best is None or key > best[2]:
            best = (b, m, key)
    if best is None:
        raise ValueError("no barrier choice satisfies the buffer bound")
    return best[0], best[1]
