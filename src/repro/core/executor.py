"""SpTTN loop-nest execution (paper §5.1, Algorithm 2) — three engines.

1. :func:`reference_execute` — a *literal* implementation of Algorithm 2:
   recursive loop-nest generation over the CSF tree with buffer reset rules.
   Pure numpy, exponentially slow, used as the semantic oracle.

2. :class:`VectorizedExecutor` — the XLA engine.  The same fused
   loop-nest plan is compiled to a vectorized JAX program:
     * sparse loops          -> flattened fiber arrays (gather / segment_sum)
     * innermost dense loops -> a single einsum/dot_general (MXU; the
                                paper's BLAS offload, §5.1/Fig 7)
     * loop fusion depth     -> the CSF level at which each intermediate is
                                materialized (nnz^(I1..Ip) x dense buffer)
   This is the TPU adaptation documented in DESIGN.md §3.

3. ``backend="pallas"`` — :class:`repro.kernels.codegen.PallasPlanExecutor`,
   a code generator that lowers the same plan to fused Pallas TPU kernels
   (block-segment grids + VMEM accumulators, DESIGN.md §6).

4. ``backend="pallas-gpu"`` — the same code generator driving the
   Mosaic-GPU-style stage lowering (split-K over segment ranges + a
   segment-combine pass, docs/backends.md): GPU grids guarantee no
   sequential execution, so the TPU lowering's revisited VMEM
   accumulator is replaced, behind the same target-neutral stage IR.

Select an engine with :func:`make_executor`; all four share one
semantics.
"""
from __future__ import annotations

import dataclasses
import json
import string
from collections.abc import Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis.diagnostics import (BACKENDS, PALLAS_BACKENDS,
                                        PALLAS_TARGETS)
from repro.core.loopnest import LoopOrder, buffer_indices
from repro.core.paths import ContractionPath, Term, consumer_map
from repro.core.spec import SpTTNSpec
from repro.sparse.csf import CSFTensor, level_segments

# The three execution engines (DESIGN.md §3/§6) live in ``BACKENDS``,
# owned by the static verifier (repro.analysis.invariants) and
# re-exported here: ``backend`` is a plan attribute — the autotuner
# measures schedules per backend and the winner's backend is persisted
# with the plan — and verification must share the same vocabulary.


# =========================================================================== #
# Plan serialization (DESIGN.md §4) — plans are pattern-static, so a chosen
# schedule survives process restarts via the autotuner's disk cache.
# Version 2 added the ``backend`` field; version 3 added the ``mesh``
# shard-context field (DESIGN.md §7); version 4 added the ``fused`` flag
# (single-kernel chain lowering on the Pallas backend, DESIGN.md §6);
# version 5 added the ``block`` field (the tuned Pallas fiber block size,
# DESIGN.md §8 — ``null`` means engine default / non-Pallas backend);
# version 6 adds the ``slice_mode``/``slice_chunks`` fields (the
# memory-budgeted slicing decision of DESIGN.md §10 — ``null``/1 means
# the plan fits its budget, or was never budgeted).
# Any other version is rejected — the forward/backward-compat rule is
# "re-plan, never guess".
# =========================================================================== #
PLAN_JSON_VERSION = 6


def _operand_to_dict(op) -> dict:
    return {"name": op.name, "indices": list(op.indices),
            "sparse": bool(op.is_sparse)}


def _operand_from_dict(d):
    from repro.core.paths import Operand
    return Operand(name=d["name"], indices=tuple(d["indices"]),
                   is_sparse=bool(d["sparse"]))


def plan_to_dict(plan) -> dict:
    """Serialize an :class:`~repro.core.planner.SpTTNPlan` to plain JSON
    types.  Everything a plan holds is structural (names, index tuples,
    dims) plus float diagnostics, so the round trip is exact."""
    spec = plan.spec
    return {
        "version": PLAN_JSON_VERSION,
        "spec": {
            "inputs": [_operand_to_dict(t) for t in spec.inputs],
            "output": _operand_to_dict(spec.output),
            "dims": {k: int(v) for k, v in spec.dims.items()},
        },
        "path": [{"lhs": _operand_to_dict(t.lhs),
                  "rhs": _operand_to_dict(t.rhs),
                  "out": _operand_to_dict(t.out)} for t in plan.path],
        "order": [list(a) for a in plan.order],
        "cost": plan.cost,
        "flops": plan.flops,
        "depth": plan.depth,
        "backend": plan.backend,
        "mesh": None if plan.mesh is None else dict(plan.mesh),
        "fused": bool(plan.fused),
        "block": None if plan.block is None else int(plan.block),
        "slice_mode": plan.slice_mode,
        "slice_chunks": int(plan.slice_chunks),
    }


def plan_from_dict(doc: dict):
    # lazy: core.executor is imported during repro.core package init,
    # before repro.analysis.invariants can finish (it imports core
    # submodules); only the leaf diagnostics module is safe at top level
    from repro.analysis.invariants import (check_block, check_mesh,
                                           check_slice)
    from repro.core.paths import Term
    from repro.core.planner import SpTTNPlan
    if doc.get("version") != PLAN_JSON_VERSION:
        # found vs expected, spelled out: version triage on a corrupt or
        # stale cache must never be guesswork [SPTTN-E060]
        raise ValueError(
            f"unsupported plan version {doc.get('version')!r}: plan JSON "
            f"v{doc.get('version')}, expected v{PLAN_JSON_VERSION}; "
            "re-plan, never guess [SPTTN-E060]")
    sd = doc["spec"]
    spec = SpTTNSpec(
        inputs=tuple(_tensor_ref(t) for t in sd["inputs"]),
        output=_tensor_ref(sd["output"]),
        dims=dict(sd["dims"]))
    path = tuple(Term(lhs=_operand_from_dict(t["lhs"]),
                      rhs=_operand_from_dict(t["rhs"]),
                      out=_operand_from_dict(t["out"]))
                 for t in doc["path"])
    order = tuple(tuple(a) for a in doc["order"])
    backend = doc.get("backend", "xla")
    if backend not in BACKENDS:
        raise ValueError(f"unknown plan backend {backend!r}; expected one "
                         f"of {BACKENDS} [SPTTN-E040]")
    mesh = doc.get("mesh")
    for d in check_mesh(mesh):
        raise ValueError(f"{d.message} [{d.code}]")
    fused = doc.get("fused", False)
    if not isinstance(fused, bool):
        raise ValueError(f"plan fused must be a boolean, got {fused!r}")
    block = doc.get("block")
    if block is not None and (not isinstance(block, int)
                              or isinstance(block, bool)):
        raise ValueError("plan block must be a positive multiple of 8 "
                         f"or null, got {block!r}")
    for d in check_block(block):
        # the sweep only ever emits sublane-aligned blocks (DESIGN.md §8);
        # accepting a misaligned one here would let compiled-mode replay
        # silently round it — rejected, never coerced
        raise ValueError("plan block must be a positive multiple of 8 "
                         f"or null, got {block!r} [{d.code}]")
    smode = doc.get("slice_mode")
    schunks = doc.get("slice_chunks", 1)
    if smode is not None and not isinstance(smode, str):
        raise ValueError(f"plan slice_mode must be a string or null, "
                         f"got {smode!r}")
    if (not isinstance(schunks, int) or isinstance(schunks, bool)
            or schunks < 1):
        raise ValueError(f"plan slice_chunks must be a positive int, "
                         f"got {schunks!r}")
    # the decision is only ever stamped for a real split of a dense mode
    # (DESIGN.md §10); anything else is a foreign/corrupt doc — rejected
    # by the verifier's slice-kind invariants, never coerced
    for d in check_slice(spec, smode, schunks):
        raise ValueError(f"plan {d.message} [{d.code}]")
    return SpTTNPlan(spec=spec, path=path, order=order, cost=doc["cost"],
                     flops=doc["flops"], depth=doc["depth"], backend=backend,
                     mesh=mesh, fused=fused, block=block,
                     slice_mode=smode, slice_chunks=schunks)


def _tensor_ref(d):
    from repro.core.spec import TensorRef
    return TensorRef(name=d["name"], indices=tuple(d["indices"]),
                     is_sparse=bool(d["sparse"]))


def plan_to_json(plan) -> str:
    return json.dumps(plan_to_dict(plan), sort_keys=True)


def plan_from_json(s: str):
    return plan_from_dict(json.loads(s))


# =========================================================================== #
# Reference engine — Algorithm 2, literally
# =========================================================================== #
def _children_ptr(csf: CSFTensor, p: int) -> np.ndarray:
    """Start offsets of each level-(p-1) fiber's children among level-p
    fibers (contiguous because coordinates are lexicographically sorted)."""
    nparent = csf.nfib[p - 1] if p > 1 else 1
    if csf.nfib.get(p, 0) == 0:
        return np.zeros(nparent + 1, dtype=np.int64)
    parents = csf.parent[p] if p > 1 else np.zeros(csf.nfib[p], dtype=np.int32)
    return np.searchsorted(parents, np.arange(nparent + 1))


def reference_execute(spec: SpTTNSpec, path: ContractionPath,
                      order: LoopOrder, csf: CSFTensor,
                      factors: Mapping[str, np.ndarray]) -> np.ndarray:
    """Execute a fused loop nest exactly as Algorithm 2 would (numpy loops).

    Returns the DENSE output (sparse-pattern outputs are densified so tests
    can compare against einsum oracles directly).
    """
    spos = {s: i for i, s in enumerate(spec.sparse_indices)}
    cons = consumer_map(path)
    binds = buffer_indices(path, order)
    dims = spec.dims

    # dense buffer allocation (reference keeps buffers at full declared size)
    bufs: dict[str, np.ndarray] = {}
    for u, inds in binds.items():
        bufs[path[u].out.name] = np.zeros([dims[i] for i in inds],
                                          dtype=np.float64)
    buf_inds = {path[u].out.name: inds for u, inds in binds.items()}
    out_arr = np.zeros([dims[i] for i in spec.output.indices],
                       dtype=np.float64)

    ptr = {p: _children_ptr(csf, p) for p in range(1, csf.order + 1)}

    def term_value(op, env, fibers):
        if op.name in factors:
            return factors[op.name][tuple(env[i] for i in op.indices)]
        if op.is_sparse and op.name == spec.sparse_input.name:
            # the sparse tensor's term always has a full fiber chain: its
            # sparse loops appear in storage order on the leaf's root path
            assert len(fibers) == csf.order, "broken CSF chain at sparse leaf"
            return csf.values[fibers[-1]]
        b = bufs[op.name]
        return b[tuple(env[i] for i in buf_inds[op.name])]

    def exec_term(tid: int, env, fibers):
        t = path[tid]
        val = term_value(t.lhs, env, fibers) * term_value(t.rhs, env, fibers)
        if t.out.name == "OUT":
            out_arr[tuple(env[i] for i in spec.output.indices)] += val
        else:
            bufs[t.out.name][tuple(env[i] for i in buf_inds[t.out.name])] += val

    def loop_nest(seq, env, fibers):
        """seq: (term_id, remaining_order) pairs; ``fibers`` is the chain of
        CSF fiber ids bound so far (levels 1..len(fibers) consecutively).

        Buffer reset per Algorithm 2: a producer/consumer pair whose fused
        loops diverge at this level has a buffer private to one iteration of
        the enclosing loops, so it is zeroed here (they never rejoin deeper,
        hence the reset fires exactly once per enclosing iteration)."""
        pos_in = {tid: n for n, (tid, _) in enumerate(seq)}
        for u, v in cons.items():
            if u in pos_in and v in pos_in:
                if not _same_group(seq, pos_in[u], pos_in[v]):
                    bufs[path[u].out.name][...] = 0.0

        i = 0
        while i < len(seq):
            tid, rem = seq[i]
            if not rem:
                exec_term(tid, env, fibers)
                i += 1
                continue
            q = rem[0]
            group = []
            j = i
            while j < len(seq) and seq[j][1] and seq[j][1][0] == q:
                group.append((seq[j][0], seq[j][1][1:]))
                j += 1
            lvl = spos[q] + 1 if q in spos else None
            if lvl is not None and len(fibers) == lvl - 1:
                # sparse loop with intact chain: iterate CSF children
                parent = fibers[-1] if fibers else 0
                for fib in range(ptr[lvl][parent], ptr[lvl][parent + 1]):
                    env2 = dict(env)
                    env2[q] = int(csf.coord[lvl][fib])
                    loop_nest(group, env2, fibers + (fib,))
            else:
                # dense loop (also the correct semantics for a sparse index
                # whose CSF chain is broken — all reads are then from dense
                # buffers/factors, e.g. a non-prefix intermediate)
                for v in range(dims[q]):
                    env2 = dict(env)
                    env2[q] = v
                    loop_nest(group, env2, fibers)
            i = j
        return

    def _same_group(seq, iu, iv):
        """True if positions iu..iv all share the same leading index."""
        ru = seq[iu][1]
        if not ru:
            return False
        q = ru[0]
        for t in range(iu, iv + 1):
            r = seq[t][1]
            if not r or r[0] != q:
                return False
        return True

    loop_nest([(i, a) for i, a in enumerate(order)], {}, ())
    return out_arr


def dense_oracle(spec: SpTTNSpec, csf: CSFTensor,
                 factors: Mapping[str, np.ndarray]) -> np.ndarray:
    """np.einsum over densified operands — the ultimate ground truth."""
    letters = {}
    for i in spec.all_indices:
        letters[i] = string.ascii_lowercase[len(letters)]
    operands, subs = [], []
    for t in spec.inputs:
        if t.is_sparse:
            operands.append(csf.coo.to_dense().astype(np.float64))
        else:
            operands.append(np.asarray(factors[t.name], dtype=np.float64))
        subs.append("".join(letters[i] for i in t.indices))
    out_sub = "".join(letters[i] for i in spec.output.indices)
    return np.einsum(",".join(subs) + "->" + out_sub, *operands)


# =========================================================================== #
# Vectorized JAX engine
# =========================================================================== #
@dataclasses.dataclass
class FiberVal:
    """A tensor carried on the level-p fibers of the sparse tensor:
    array shape = (nfib_p, *dense_dims)."""
    array: jnp.ndarray
    level: int
    dense: tuple[str, ...]


@dataclasses.dataclass
class DenseVal:
    array: jnp.ndarray
    indices: tuple[str, ...]


@dataclasses.dataclass
class CSFArrays:
    """Device-resident CSF (one-time upload; pattern is fixed)."""
    values: jnp.ndarray
    fiber_coord: dict[int, dict[int, jnp.ndarray]]  # level -> mode -> coords
    seg: dict[tuple[int, int], jnp.ndarray]         # (child, parent) -> map
    nfib: dict[int, int]
    order: int
    shape: tuple[int, ...]
    host: "CSFTensor | None" = None   # source tensor (reference engine)

    @classmethod
    def from_csf(cls, csf: CSFTensor) -> "CSFArrays":
        fiber_coord: dict[int, dict[int, jnp.ndarray]] = {}
        for p in range(1, csf.order + 1):
            fc = csf.fiber_coords(p)
            fiber_coord[p] = {m: jnp.asarray(fc[:, m]) for m in range(p)}
        seg = {}
        for child in range(1, csf.order + 1):
            for par in range(0, child):
                seg[(child, par)] = jnp.asarray(
                    level_segments(csf, child, par))
        return cls(values=jnp.asarray(csf.values),
                   fiber_coord=fiber_coord, seg=seg,
                   nfib=dict(csf.nfib), order=csf.order,
                   shape=csf.shape, host=csf)


class VectorizedExecutor:
    """Compile a (path, order) plan into a JAX function over CSF arrays.

    The plan's fused sparse depth per intermediate decides the CSF level at
    which it is materialized; trailing dense loops become one einsum.
    """

    def __init__(self, spec: SpTTNSpec, path: ContractionPath,
                 order: LoopOrder):
        self.spec = spec
        self.path = path
        self.order = order
        self.spos = {s: i for i, s in enumerate(spec.sparse_indices)}
        from repro.core.loopnest import fused_sparse_depth
        self.fuse_depth = fused_sparse_depth(path, order, spec.sparse_indices)
        self._letter = {}
        for i in spec.all_indices:
            self._letter[i] = string.ascii_lowercase[len(self._letter)]

    # -- helpers -------------------------------------------------------- #
    def _sparse_level(self, inds: Sequence[str]) -> int:
        return max((self.spos[i] + 1 for i in inds if i in self.spos),
                   default=0)

    def _is_prefix(self, inds: Sequence[str]) -> bool:
        """True if the sparse indices of ``inds`` form a CSF storage prefix."""
        sp = sorted(self.spos[i] for i in inds if i in self.spos)
        return sp == list(range(len(sp)))

    def _lift_dense_factor(self, csf: CSFArrays, arr: jnp.ndarray,
                           inds: tuple[str, ...], level: int
                           ) -> tuple[jnp.ndarray, tuple[str, ...]]:
        """Gather a dense operand's rows onto level-``level`` fibers, one
        gather per sparse index it carries."""
        sp_axes = [(ax, self.spos[i] ) for ax, i in enumerate(inds)
                   if i in self.spos]
        if not sp_axes:
            return arr, inds
        take = arr
        dense_inds = tuple(i for i in inds if i not in self.spos)
        # build advanced-index tuple
        index_tuple = []
        for ax, i in enumerate(inds):
            if i in self.spos:
                index_tuple.append(csf.fiber_coord[level][self.spos[i]])
            else:
                index_tuple.append(slice(None))
        # numpy-style mixed advanced indexing: all advanced indices are 1-D
        # fiber-length vectors -> broadcast to a single fiber axis in front
        out = take[tuple(index_tuple)]
        # jnp places the broadcast advanced axis first when advanced indices
        # are non-contiguous; when contiguous it stays in place.  Normalize:
        adv_pos = [ax for ax, i in enumerate(inds) if i in self.spos]
        contiguous = adv_pos == list(range(adv_pos[0], adv_pos[0] + len(adv_pos)))
        if contiguous and adv_pos[0] != 0:
            # fiber axis sits at adv_pos[0]; move to front
            out = jnp.moveaxis(out, adv_pos[0], 0)
        return out, dense_inds

    def _einsum(self, a: jnp.ndarray, ai: Sequence[str],
                b: jnp.ndarray, bi: Sequence[str],
                oi: Sequence[str], fiber: bool) -> jnp.ndarray:
        L = self._letter
        batch = "Z" if fiber else ""
        sa = batch + "".join(L[i] for i in ai)
        sb = batch + "".join(L[i] for i in bi)
        so = batch + "".join(L[i] for i in oi)
        return jnp.einsum(f"{sa},{sb}->{so}", a, b)

    # -- main ----------------------------------------------------------- #
    def _get_operand(self, csf: CSFArrays, factors: Mapping, env: dict,
                     op) -> "FiberVal | DenseVal":
        if op.is_sparse and op.name == self.spec.sparse_input.name:
            return FiberVal(csf.values, csf.order, ())
        if op.name in factors:
            return DenseVal(jnp.asarray(factors[op.name]), op.indices)
        return env[op.name]

    def _to_dense(self, csf: CSFArrays, v: "FiberVal | DenseVal",
                  want: tuple[str, ...]) -> jnp.ndarray:
        """Materialize onto a dense array with index order ``want``."""
        spec = self.spec
        if isinstance(v, DenseVal):
            perm = [v.indices.index(i) for i in want]
            return jnp.transpose(v.array, perm)
        # scatter fiber rows into a dense array over its sparse prefix
        sp_inds = tuple(spec.sparse_indices[:v.level])
        full = sp_inds + v.dense
        shape = [spec.dims[i] for i in full]
        coords = tuple(csf.fiber_coord[v.level][m] for m in range(v.level))
        out = jnp.zeros(shape, v.array.dtype).at[coords].add(
            v.array, unique_indices=True)  # distinct fibers: no dups
        perm = [full.index(i) for i in want]
        return jnp.transpose(out, perm)

    def _chain_len(self, tid: int) -> int:
        """Number of consecutive terms starting at ``tid`` this engine
        executes as one unit.  The XLA engine is strictly one term per
        lowering; the Pallas engine overrides this with its detected
        fused chains (DESIGN.md §6)."""
        return 1

    def _exec_chain(self, csf: CSFArrays, factors: Mapping, env: dict,
                    tid: int, length: int):
        raise NotImplementedError   # pragma: no cover - chain engines only

    def _exec_term(self, csf: CSFArrays, factors: Mapping, env: dict,
                   term: Term) -> "FiberVal | DenseVal":
        """Execute one contraction term, returning its intermediate value
        (a final term's value is materialized by ``_materialize_output``)."""
        a = self._get_operand(csf, factors, env, term.lhs)
        b = self._get_operand(csf, factors, env, term.rhs)
        out_inds = term.out.indices
        term_sp = [i for i in term.indices if i in self.spos]
        prefix_ok = (self._is_prefix(term.indices)
                     and self._is_prefix(out_inds))
        is_final = term.out.name == "OUT"

        if term_sp and prefix_ok and (isinstance(a, FiberVal)
                                      or isinstance(b, FiberVal)):
            return self._exec_fiber_term(csf, term, a, b)
        if (term_sp and is_final and self._is_prefix(term.indices)
                and (isinstance(a, FiberVal) or isinstance(b, FiberVal))):
            # final term keeping a non-prefix sparse subset (e.g. TTTc's
            # OUT(e,n)): einsum at the term level, then scatter-add by
            # the kept coordinate columns (implicitly summing the rest)
            arr = self._exec_final_scatter(csf, term, a, b)
            return DenseVal(arr, self.spec.output.indices)
        # dense fallback (covers dense x dense and non-prefix cases)
        ai = tuple(term.lhs.indices)
        bi = tuple(term.rhs.indices)
        da = self._to_dense(csf, a, ai)
        db = self._to_dense(csf, b, bi)
        arr = self._einsum(da, ai, db, bi, out_inds, fiber=False)
        return DenseVal(arr, out_inds)

    def _materialize_output(self, csf: CSFArrays,
                            val: "FiberVal | DenseVal") -> jnp.ndarray:
        spec = self.spec
        if isinstance(val, DenseVal):
            perm = [val.indices.index(i) for i in spec.output.indices]
            return jnp.transpose(val.array, perm)
        if spec.output_is_sparse:
            # same-sparsity output: return leaf values (level = order)
            assert val.level == csf.order and not val.dense
            return val.array
        return self._to_dense(csf, val, spec.output.indices)

    def __call__(self, csf: CSFArrays,
                 factors: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        env: dict[str, FiberVal | DenseVal] = {}
        tid, n = 0, len(self.path)
        while tid < n:
            length = self._chain_len(tid)
            if length > 1:
                val = self._exec_chain(csf, factors, env, tid, length)
                term = self.path[tid + length - 1]
                tid += length
            else:
                term = self.path[tid]
                val = self._exec_term(csf, factors, env, term)
                tid += 1
            if term.out.name == "OUT":
                return self._materialize_output(csf, val)
            env[term.out.name] = val
        raise AssertionError("path had no final term")

    # ------------------------------------------------------------------ #
    def _lift(self, csf: CSFArrays, v, ref, lvl: int):
        """Bring an operand onto level-``lvl`` fibers."""
        if isinstance(v, FiberVal):
            arr = v.array
            if v.level < lvl:
                arr = arr[csf.seg[(lvl, v.level)]]
            return arr, v.dense
        return self._lift_dense_factor(csf, v.array, ref.indices, lvl)

    def _exec_final_scatter(self, csf: CSFArrays, term: Term, a, b):
        """Final term whose kept sparse indices are not a storage prefix:
        scatter-add fiber rows by the kept coordinate columns."""
        spec = self.spec
        lvl = self._sparse_level(term.indices)
        fa, da = self._lift(csf, a, term.lhs, lvl)
        fb, db = self._lift(csf, b, term.rhs, lvl)
        out_inds = spec.output.indices
        out_sp = [i for i in out_inds if i in self.spos]
        out_dense = tuple(i for i in out_inds if i not in self.spos)
        arr = self._fiber_contract(csf, fa, da, fb, db, out_dense, lvl, lvl)
        coords = tuple(csf.fiber_coord[lvl][self.spos[i]] for i in out_sp)
        shape = [spec.dims[i] for i in out_sp] + \
            [spec.dims[i] for i in out_dense]
        full = tuple(out_sp) + out_dense
        out = jnp.zeros(shape, arr.dtype).at[coords].add(arr)
        perm = [full.index(i) for i in out_inds]
        return jnp.transpose(out, perm) if perm != list(range(len(perm))) \
            else out

    def _exec_fiber_term(self, csf: CSFArrays, term: Term,
                         a: "FiberVal | DenseVal",
                         b: "FiberVal | DenseVal") -> FiberVal:
        """sparse-structured term: lift to the term's CSF level, contract the
        dense dims (MXU), segment-reduce to the output's level."""
        lvl = self._sparse_level(term.indices)
        out_lvl = self._sparse_level(term.out.indices)

        fa, da = self._lift(csf, a, term.lhs, lvl)
        fb, db = self._lift(csf, b, term.rhs, lvl)
        sp = set(self.spos)
        out_dense = tuple(i for i in term.out.indices if i not in sp)
        arr = self._fiber_contract(csf, fa, da, fb, db, out_dense, lvl,
                                   out_lvl)
        if out_lvl == 0:
            return DenseVal(arr, out_dense)      # fully contracted prefix
        return FiberVal(arr, out_lvl, out_dense)

    def _fiber_contract(self, csf: CSFArrays, fa, da, fb, db,
                        out_dense: tuple[str, ...], lvl: int,
                        out_lvl: int) -> jnp.ndarray:
        """Contract two level-``lvl`` operands and reduce to ``out_lvl``.

        The overridable lowering unit shared by the XLA and Pallas engines:
        dense-contracted indices collapse into one einsum (BLAS/MXU) and
        the sparse reduction becomes a segmented sum.  ``out_lvl == lvl``
        means no sparse reduction (per-fiber output); ``out_lvl == 0``
        returns the dense array of shape ``out_dense``.
        """
        arr = self._einsum(fa, da, fb, db, out_dense, fiber=True)
        if out_lvl < lvl:
            seg = csf.seg[(lvl, out_lvl)] if out_lvl > 0 else jnp.zeros(
                arr.shape[0], jnp.int32)
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            # CSF order is lexicographic: segment ids are sorted, which
            # lets XLA lower the reduction as a contiguous segmented scan
            # instead of a scatter (§Perf wall-clock iteration 1)
            arr = jax.ops.segment_sum(arr, seg, num_segments=nseg,
                                      indices_are_sorted=True)
            if out_lvl == 0:
                arr = arr[0]
        return arr


def execute_unfactorized(spec: SpTTNSpec, csf: CSFArrays,
                         factors: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
    """The 'unfactorized' schedule (paper §2.4.1): all factors gathered to
    the leaves and multiplied in one pass (TACO/COMET default).  Kept as a
    baseline for the benchmarks."""
    spos = {s: i for i, s in enumerate(spec.sparse_indices)}
    letters = {}
    for i in spec.all_indices:
        letters[i] = string.ascii_lowercase[len(letters)]
    lvl = csf.order
    operands = [csf.values]
    subs = ["Z"]
    for t in spec.inputs:
        if t.is_sparse:
            continue
        arr = jnp.asarray(factors[t.name])
        idx = []
        for ax, i in enumerate(t.indices):
            if i in spos:
                idx.append(csf.fiber_coord[lvl][spos[i]])
            else:
                idx.append(slice(None))
        g = arr[tuple(idx)]
        adv = [ax for ax, i in enumerate(t.indices) if i in spos]
        if adv and adv != list(range(adv[0], adv[0] + len(adv))):
            pass  # jnp already moved fiber axis front
        elif adv and adv[0] != 0:
            g = jnp.moveaxis(g, adv[0], 0)
        operands.append(g)
        subs.append("Z" + "".join(letters[i] for i in t.indices
                                  if i not in spos))
    out_sp = [i for i in spec.output.indices if i in spos]
    out_dn = [i for i in spec.output.indices if i not in spos]
    expr = ",".join(subs) + "->Z" + "".join(letters[i] for i in out_dn)
    per_leaf = jnp.einsum(expr, *operands)
    if spec.output_is_sparse:
        return per_leaf
    p_out = len(out_sp)
    if p_out < lvl:
        seg = csf.seg[(lvl, p_out)] if p_out > 0 else jnp.zeros(
            per_leaf.shape[0], jnp.int32)
        nseg = csf.nfib[p_out] if p_out > 0 else 1
        per_leaf = jax.ops.segment_sum(per_leaf, seg, num_segments=nseg,
                                       indices_are_sorted=True)
    # scatter onto the dense output over the sparse output indices
    full = tuple(out_sp) + tuple(out_dn)
    if p_out == 0:
        out = per_leaf[0]
    else:
        shape = [spec.dims[i] for i in full]
        coords = tuple(csf.fiber_coord[p_out][m] for m in range(p_out))
        out = jnp.zeros(shape, per_leaf.dtype).at[coords].add(
            per_leaf, unique_indices=True)
    perm = [full.index(i) for i in spec.output.indices]
    return jnp.transpose(out, perm) if perm != list(range(len(perm))) else out


# =========================================================================== #
# Engine registry
# =========================================================================== #
class ReferenceExecutor:
    """Algorithm-2 interpreter behind the common executor signature.

    Accepts a host :class:`CSFTensor` or a :class:`CSFArrays` built via
    :meth:`CSFArrays.from_csf` (which retains the host tensor).  Output is
    always the dense numpy array; sparse-pattern outputs are densified —
    callers needing leaf values should use the vectorized engines.
    """

    def __init__(self, spec: SpTTNSpec, path: ContractionPath,
                 order: LoopOrder):
        self.spec = spec
        self.path = path
        self.order = order

    def __call__(self, csf, factors: Mapping) -> np.ndarray:
        if isinstance(csf, CSFArrays):
            if csf.host is None:
                raise ValueError(
                    "reference backend needs the host CSFTensor; build "
                    "CSFArrays via from_csf or pass the CSFTensor directly")
            csf = csf.host
        np_factors = {k: np.asarray(v) for k, v in factors.items()}
        return reference_execute(self.spec, self.path, self.order, csf,
                                 np_factors)


def default_interpret() -> bool:
    """Pallas kernels run in interpret mode everywhere but real TPUs."""
    return jax.default_backend() != "tpu"


# The full extra-kwarg vocabulary of the engines: all three are Pallas
# code-generator options (DESIGN.md §6/§8).  Anything else is a typo and
# is rejected — historically e.g. ``blocks=128`` was silently swallowed
# and the engine ran with its default block size.
ENGINE_KWARGS = ("block", "strategy", "tile_align")


def _check_engine_kwargs(kwargs: Mapping, backend: str, who: str) -> None:
    unknown = sorted(k for k in kwargs if k not in ENGINE_KWARGS)
    if unknown:
        import difflib
        hints = []
        for k in unknown:
            close = difflib.get_close_matches(k, ENGINE_KWARGS, n=1)
            if close:
                hints.append(f"{k!r} -> did you mean {close[0]!r}?")
        hint = ("; " + "; ".join(hints)) if hints else ""
        raise ValueError(
            f"{who}() got unknown argument(s) {unknown}; valid engine "
            f"options are {sorted(ENGINE_KWARGS)} (plus 'interpret' and "
            f"'backend'){hint}")
    if kwargs and backend not in PALLAS_BACKENDS:
        raise ValueError(
            f"{who}() argument(s) {sorted(kwargs)} apply only to the "
            f"Pallas backends {PALLAS_BACKENDS}, got backend={backend!r}")


def make_executor(spec: SpTTNSpec, path: ContractionPath, order: LoopOrder,
                  backend: str = "xla", interpret: bool | None = None,
                  **kwargs):
    """Instantiate an execution engine for a (path, order) schedule.

    All engines share the call signature ``ex(csf_arrays, factors)``.
    ``backend`` is one of :data:`BACKENDS`; ``interpret=None`` resolves via
    :func:`default_interpret` (True off-TPU).  Extra kwargs reach the
    Pallas code generator (:data:`ENGINE_KWARGS`: ``block``, ``strategy``,
    ``tile_align``); unknown kwargs — or Pallas options on a non-Pallas
    backend — raise ``ValueError`` instead of being silently dropped.

    >>> import numpy as np
    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> from repro.sparse import build_csf, random_sparse
    >>> spec = S.mttkrp(8, 6, 5, 4)
    >>> csf = build_csf(random_sparse((8, 6, 5), 0.2, seed=0))
    >>> rng = np.random.default_rng(0)
    >>> factors = {"B": rng.standard_normal((6, 4)).astype(np.float32),
    ...            "C": rng.standard_normal((5, 4)).astype(np.float32)}
    >>> p = plan(spec, nnz_levels=csf.nnz_levels())
    >>> ex = make_executor(spec, p.path, p.order, backend="xla")
    >>> out = ex(CSFArrays.from_csf(csf), factors)
    >>> out.shape
    (8, 4)
    >>> make_executor(spec, p.path, p.order, blocks=128)
    Traceback (most recent call last):
        ...
    ValueError: make_executor() got unknown argument(s) ['blocks']; ...
    """
    _check_engine_kwargs(kwargs, backend, "make_executor")
    if backend == "xla":
        return VectorizedExecutor(spec, path, order)
    if backend in PALLAS_BACKENDS:
        from repro.kernels.codegen import PallasPlanExecutor
        return PallasPlanExecutor(spec, path, order, interpret=interpret,
                                  target=PALLAS_TARGETS[backend], **kwargs)
    if backend == "reference":
        return ReferenceExecutor(spec, path, order)
    raise ValueError(f"unknown backend {backend!r}; expected one of "
                     f"{BACKENDS}")


def execute_plan(plan, csf, factors: Mapping, backend: str | None = None,
                 memory_budget: int | None = None, **kwargs):
    """Run an :class:`~repro.core.planner.SpTTNPlan` end to end, honoring
    the plan's tuned backend unless overridden.

    ``memory_budget`` (bytes) prices the plan's working set against the
    operand's actual nnz profile and, when over budget, replays the same
    schedule per chunk of one dense mode
    (:func:`repro.core.slicing.sliced_execute`, DESIGN.md §10).  With no
    explicit budget, a plan stamped ``slice_chunks > 1`` at planning time
    replays sliced as stamped.  Both compose with sharded operands: the
    budget applies within each shard.

    ``csf`` is either a single operand (a :class:`CSFArrays` /
    :class:`~repro.sparse.csf.CSFTensor`) or a *sharded* operand: a
    list/tuple of per-shard CSF tensors that partition the nonzeros of one
    global tensor **in global coordinates** (every shard keeps the full
    declared ``dims``).  For a dense output each shard's partial output is
    exact on the rows its nonzeros touch and zero elsewhere, so the global
    result is the plain sum of the per-shard partials — the host-side
    mirror of the distributed engine's psum (DESIGN.md §7).  ``factors``
    may then be one mapping (replicated operands) or a per-shard sequence.
    Sharded execution of a same-sparsity (TTTP-like) output is rejected:
    leaf values are per-shard local and need the distributed engine's
    layout to reassemble.

    >>> import numpy as np
    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> from repro.sparse import build_csf, random_sparse
    >>> spec = S.mttkrp(8, 6, 5, 4)
    >>> csf = build_csf(random_sparse((8, 6, 5), 0.2, seed=0))
    >>> rng = np.random.default_rng(0)
    >>> factors = {"B": rng.standard_normal((6, 4)).astype(np.float32),
    ...            "C": rng.standard_normal((5, 4)).astype(np.float32)}
    >>> p = plan(spec, nnz_levels=csf.nnz_levels())
    >>> out = execute_plan(p, CSFArrays.from_csf(csf), factors)
    >>> out.shape
    (8, 4)
    """
    _check_engine_kwargs({k: v for k, v in kwargs.items()
                          if k != "interpret"},
                         backend or plan.backend, "execute_plan")
    # static pre-flight: every invariant an engine would trip over deep
    # inside a lowering is rejected here, before anything compiles, with
    # a structured SPTTN-E* diagnostic (DESIGN.md §11)
    from repro.analysis import verify_plan
    verify_plan(plan, backend=backend or plan.backend).raise_if_error(
        "execute_plan")
    if isinstance(csf, (list, tuple)):
        if plan.spec.output_is_sparse:
            raise ValueError(
                "sharded operands with a same-sparsity output need the "
                "distributed engine (repro.distributed.spttn_dist); "
                "per-shard leaf values cannot be summed")
        if not csf:
            raise ValueError("empty shard list")
        per_shard = (list(factors) if isinstance(factors, (list, tuple))
                     else [factors] * len(csf))
        if len(per_shard) != len(csf):
            raise ValueError(
                f"{len(csf)} shards but {len(per_shard)} factor mappings")
        total = None
        for shard, f in zip(csf, per_shard):
            part = jnp.asarray(execute_plan(plan, shard, f,
                                            backend=backend,
                                            memory_budget=memory_budget,
                                            **kwargs))
            total = part if total is None else total + part
        return total
    if memory_budget is not None:
        # price against the operand's true profile; slice only if needed
        from repro.core import slicing
        plan = slicing.stamp_plan_slicing(plan, slicing.nnz_levels_of(csf),
                                          memory_budget)
    if getattr(plan, "slice_chunks", 1) > 1:
        from repro.core.slicing import sliced_execute
        return sliced_execute(plan, csf, factors, backend=backend, **kwargs)
    resolved = backend or plan.backend
    if resolved in PALLAS_BACKENDS and getattr(plan, "fused", False):
        # a fused-winner plan replays through the chain lowering it was
        # tuned with (DESIGN.md §6; one kernel on TPU, split-K + link
        # combines on GPU)
        kwargs.setdefault("strategy", "fused")
    if resolved in PALLAS_BACKENDS and getattr(plan, "block", None):
        # ... and with the exact fiber block size that won (DESIGN.md §8)
        kwargs.setdefault("block", plan.block)
    ex = make_executor(plan.spec, plan.path, plan.order,
                       backend=resolved, **kwargs)
    return ex(csf, factors)
