"""Algorithm 1 (paper §4.2.5): cost-optimal index order for a contraction
path, for any tree-separable cost function.

Subproblems are identified by a contiguous term subsequence ``[lo, hi)`` and
the set of already-iterated (removed) indices; with memoization the
complexity is ``O(N^3 * 2^m * m)`` versus ``O((m!)^N)`` exhaustive
(Theorem 4.9).  Returns both the best order A and the best order B whose
loop-nest forest has a different root — B is required by line 17 of the
pseudocode to preserve full fusion across peels.

The DP scores any tree-separable cost (docs/cost-models.md); on the
MTTKRP running example under :class:`~repro.core.cost.MaxBufferSize` it
finds the fully fused nest whose crossing buffer is a single scalar, and
its alternative-root order (line 17's ``B``) starts at a different loop:

>>> from repro.core import spec as S
>>> from repro.core.cost import MaxBufferSize
>>> from repro.core.planner import plan
>>> spec = S.mttkrp(8, 6, 5, 4)
>>> path = plan(spec).path
>>> res = OrderDP(path, MaxBufferSize(), spec.dims,
...               spec.sparse_indices).solve()
>>> res.order, res.cost
((('i', 'j', 'a', 'k'), ('i', 'j', 'a')), 1)
>>> res.alt_order[0][0] != res.order[0][0]
True

The sparse-order restriction (paper §5) is honored: within any term,
CSF-stored indices may only be peeled in storage order, so no valid
order ever iterates ``j`` before ``i`` inside the sparse leaf term:

>>> all(a[0] == "i" for a, *_ in [res.order])   # root loop is storage-major
True
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping, Sequence

from repro.core.cost import INF, PhiCtx, TreeCost
from repro.core.loopnest import LoopOrder
from repro.core.paths import ContractionPath, consumer_map


@dataclasses.dataclass
class DPResult:
    order: LoopOrder | None
    cost: float
    alt_order: LoopOrder | None  # best with a different root (B of Alg. 1)
    alt_cost: float


def _first_index(order: LoopOrder) -> str | None:
    """Root index of the first tree of the forest for ``order`` (None if the
    leading terms are exhausted leaves)."""
    for a in order:
        if a:
            return a[0]
        # a leading leaf breaks root-adjacency; no fusion conflict possible
        return None
    return None


class OrderDP:
    """Algorithm 1 with memoization over (lo, hi, removed)."""

    def __init__(self, path: ContractionPath, cost: TreeCost,
                 dims: Mapping[str, int],
                 sparse_storage: Sequence[str] = ()):
        self.path = path
        self.cost = cost
        self.dims = dims
        self.sparse_storage = tuple(sparse_storage)
        self.sparse = frozenset(sparse_storage)
        self.spos = {s: i for i, s in enumerate(sparse_storage)}
        self.consumer = consumer_map(path)
        self.term_inds = [t.indices for t in path]
        self._memo: dict[tuple, DPResult] = {}

    # ------------------------------------------------------------------ #
    def solve(self) -> DPResult:
        return self._order(0, len(self.path), frozenset())

    # ------------------------------------------------------------------ #
    def _remaining(self, tid: int, removed: frozenset[str]) -> tuple[str, ...]:
        return tuple(i for i in self.term_inds[tid] if i not in removed)

    def _valid_root(self, q: str, tid: int, removed: frozenset[str]) -> bool:
        """Sparse-order restriction (paper §5): within any term, sparse
        indices must be iterated in CSF storage order.  Choosing sparse ``q``
        as the next loop of term ``tid`` is valid only if ``q`` is the
        earliest remaining sparse index of that term."""
        if q not in self.sparse:
            return True
        rem_sp = sorted((i for i in self.term_inds[tid]
                         if i in self.sparse and i not in removed),
                        key=self.spos.get)
        return bool(rem_sp) and rem_sp[0] == q

    def _crossing(self, lo: int, mid: int, hi: int,
                  removed: frozenset[str]) -> tuple[tuple[str, ...], ...]:
        """Buffer edges separated by this peel: producer in [lo, mid),
        consumer in [mid, hi).  Each edge is separated by exactly one peel
        along the recursion, so costs never double count."""
        out = []
        for u in range(lo, mid):
            v = self.consumer.get(u)
            if v is not None and mid <= v < hi:
                out.append(tuple(i for i in self.path[u].out.indices
                                 if i not in removed))
        return tuple(out)

    # ------------------------------------------------------------------ #
    def _order(self, lo: int, hi: int, removed: frozenset[str]) -> DPResult:
        key = (lo, hi, removed)
        hit = self._memo.get(key)
        if hit is not None:
            return hit

        # L = ∅  (line 3)
        if lo == hi:
            res = DPResult((), self.cost.zero, None, INF)
            self._memo[key] = res
            return res

        # L[1] = ∅ — first term exhausted: it is a leaf here (line 5).
        # Its buffer edge, if the consumer is also in this subproblem, was
        # never separated by any peel: it is a fully-fused scalar — charge
        # the cost's scalar_buffer term exactly once here.
        first_rem = self._remaining(lo, removed)
        if not first_rem:
            sub = self._order(lo + 1, hi, removed)
            extra = self.cost.zero
            v = self.consumer.get(lo)
            if v is not None and lo < v < hi:
                extra = self.cost.scalar_buffer()
            res = DPResult(
                ((),) + sub.order if sub.order is not None else None,
                self.cost.combine(extra, sub.cost),
                ((),) + sub.alt_order if sub.alt_order is not None else None,
                self.cost.combine(extra, sub.alt_cost)
                if sub.alt_order is not None else sub.alt_cost)
            self._memo[key] = res
            return res

        best_cost, best_order, best_root = INF, None, None
        alt_cost, alt_order = INF, None

        for q in first_rem:  # line 8: roots are indices of the first term
            dc_cost, dc_order = INF, None
            # line 10: longest prefix of terms that all (validly) contain q
            k = 0
            while lo + k < hi:
                rem = self._remaining(lo + k, removed)
                if q not in rem or not self._valid_root(q, lo + k, removed):
                    break
                k += 1
            for s in range(1, k + 1):  # line 11
                x = self._order(lo, lo + s, removed | {q})
                if x.order is None or x.cost >= INF:
                    continue
                y = self._order(lo + s, hi, removed)
                y_order, y_cost = y.order, y.cost
                # line 17: Y must not root at q, else the forest would fuse
                if y_order is not None and _first_index(y_order) == q:
                    y_order, y_cost = y.alt_order, y.alt_cost
                if y_order is None or y_cost >= INF:
                    continue
                ctx = PhiCtx(
                    q=q, removed=removed,
                    terms_x=tuple((lo + t, self.path[lo + t])
                                  for t in range(s)),
                    crossing_out=self._crossing(lo, lo + s, hi, removed),
                    dims=self.dims, sparse=self.sparse)
                delta = self.cost.combine(self.cost.phi(ctx, x.cost), y_cost)
                if delta < dc_cost:  # line 24
                    dc_cost = delta
                    dc_order = tuple((q,) + a for a in x.order) + y_order
            if dc_order is None:
                continue
            # lines 27-31 (one candidate per distinct root q, so the demoted
            # previous best always has a different root than the new best)
            if dc_cost < best_cost:
                alt_cost, alt_order = best_cost, best_order
                best_cost, best_order, best_root = dc_cost, dc_order, q
            elif dc_cost < alt_cost:
                alt_cost, alt_order = dc_cost, dc_order

        res = DPResult(best_order, best_cost, alt_order, alt_cost)
        self._memo[key] = res
        return res


def optimal_order(path: ContractionPath, cost: TreeCost,
                  dims: Mapping[str, int],
                  sparse_storage: Sequence[str] = ()) -> tuple[LoopOrder, float]:
    """Convenience wrapper: best loop order and its cost for one path."""
    res = OrderDP(path, cost, dims, sparse_storage).solve()
    if res.order is None:
        raise ValueError("no valid loop order (check sparse-order constraints)")
    return res.order, res.cost
