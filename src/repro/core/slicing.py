"""Memory-budgeted sliced execution (out-of-core SpTTN, DESIGN.md §10).

The cost layer already *prices* a loop nest's intermediates — the
vectorized memory model :func:`repro.core.cost.buffer_bytes` is
``MaxBufferSize`` (paper Def 4.7) evaluated in bytes at fiber-level
materialization.  This module *acts* on that price: given a
``memory_budget`` in bytes, it prices a plan's peak working set
(intermediates + operands + output), and when the plan is over budget it
splits ONE dense mode into chunks and replays the *same* tuned schedule
once per chunk — chunk-restricted factors, chunk-restricted output slab —
streaming (output mode) or accumulating (contracted mode) the partials.
QTensor's slicing estimator (SNIPPETS.md) is the model: price under an
explicit cap, slice only when the cap is exceeded, never re-plan.

Design rules:

* **One cached plan.**  The slice decision is a function of
  (plan, nnz profile, budget) and is re-derived at planning/serving time;
  it never enters the plan-cache key and the cache always stores the
  *unsliced* schedule.  Budgeted and unbudgeted callers share one entry.
* **Dense modes only.**  A dense mode never appears in the CSF, so every
  chunk replays against the identical sparse operand and the identical
  segment layouts — no pattern rebuild, no re-tuning.  Slicing a *sparse*
  mode is exactly nonzero sharding, which `execute_plan` already does for
  shard lists; the two compose (slice within shard).
* **Exactness.**  Chunking a dense mode partitions either the output
  (mode kept by the output: disjoint slabs) or the contraction sum
  (mode contracted away: partial sums accumulated in float64), so sliced
  results match unsliced ones to float rounding.
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping

import numpy as np

from repro.analysis.diagnostics import PALLAS_BACKENDS
from repro.core.cost import buffer_bytes
from repro.core.loopnest import LoopOrder
from repro.core.paths import ContractionPath
from repro.core.spec import SpTTNSpec

DEFAULT_ITEMSIZE = 4   # float32 — every engine computes in f32


class MemoryBudgetError(ValueError):
    """No single-mode chunking brings the plan's working set under budget."""


@dataclasses.dataclass(frozen=True)
class SliceDecision:
    """How (and whether) a plan must be sliced to fit ``budget`` bytes.

    ``mode`` is the dense index being chunked (``None`` = fits unsliced),
    ``chunks`` the number of chunks (1 = unsliced), ``kind`` one of
    ``"none"`` / ``"output"`` (mode kept by the output: partials are
    disjoint slabs) / ``"contracted"`` (mode summed away: partials are
    accumulated).  ``peak_bytes`` is the unsliced working set and
    ``chunk_bytes`` the working set of the widest chunk — the quantity
    guaranteed ``<= budget`` when ``mode`` is not None.
    """

    mode: str | None
    chunks: int
    kind: str
    peak_bytes: int
    chunk_bytes: int


def _default_nnz_levels(spec: SpTTNSpec) -> dict[int, int]:
    """Density-agnostic profile (same default as the planner's)."""
    prod, levels = 1, {0: 1}
    for p, ind in enumerate(spec.sparse_indices, start=1):
        prod *= spec.dims[ind]
        levels[p] = prod
    return levels


def nnz_levels_of(csf) -> dict[int, int]:
    """nnz-level profile of a CSFTensor *or* device-side CSFArrays."""
    if hasattr(csf, "nnz_levels"):
        return dict(csf.nnz_levels())
    return {0: 1, **{int(p): int(n) for p, n in csf.nfib.items()}}


def _footprint(spec: SpTTNSpec, path: ContractionPath, order: LoopOrder,
               nnz_levels: Mapping[int, int], dims: Mapping[str, int],
               itemsize: int) -> int:
    """Working-set bytes of one execution pass under ``dims``:
    vectorized intermediates (the ``MaxBufferSize`` accounting in bytes,
    :func:`repro.core.cost.buffer_bytes`) + dense operands + sparse
    values + the output the pass materializes."""
    total = buffer_bytes(path, order, dims, spec.sparse_indices,
                         nnz_levels, itemsize=itemsize)
    nnz = int(nnz_levels.get(len(spec.sparse_indices), 0))
    for t in spec.inputs:
        if t.is_sparse:
            total += nnz * itemsize
        else:
            total += math.prod(dims[i] for i in t.indices) * itemsize
    if spec.output_is_sparse:
        total += nnz * itemsize
    else:
        total += math.prod(dims[i] for i in spec.output.indices) * itemsize
    return int(total)


def plan_peak_bytes(spec: SpTTNSpec, path: ContractionPath,
                    order: LoopOrder,
                    nnz_levels: Mapping[int, int] | None = None,
                    itemsize: int = DEFAULT_ITEMSIZE) -> int:
    """Peak working-set bytes of running ``(path, order)`` unsliced.

    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> spec = S.mttkrp(8, 6, 5, 4)
    >>> p = plan(spec)
    >>> plan_peak_bytes(spec, p.path, p.order, {0: 1, 1: 8, 2: 20, 3: 40})
    784
    """
    levels = (dict(nnz_levels) if nnz_levels is not None
              else _default_nnz_levels(spec))
    return _footprint(spec, path, order, levels, spec.dims, itemsize)


def _chunk_width(D: int, chunks: int) -> int:
    return -(-D // chunks)


def _min_chunks(spec: SpTTNSpec, path, order, levels, budget: int,
                mode: str, itemsize: int) -> int | None:
    """Smallest chunk count for ``mode`` that fits, or None (infeasible).
    The footprint is monotone non-increasing in the chunk count, so
    bisection over [1, dims[mode]] is exact."""
    D = spec.dims[mode]

    def fits(chunks: int) -> bool:
        dims = dict(spec.dims)
        dims[mode] = _chunk_width(D, chunks)
        return _footprint(spec, path, order, levels, dims,
                          itemsize) <= budget

    if fits(1):
        return 1
    if not fits(D):
        return None
    lo, hi = 1, D          # invariant: not fits(lo), fits(hi)
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if fits(mid):
            hi = mid
        else:
            lo = mid
    return hi


def choose_slicing(spec: SpTTNSpec, path: ContractionPath, order: LoopOrder,
                   nnz_levels: Mapping[int, int] | None,
                   memory_budget: int,
                   itemsize: int = DEFAULT_ITEMSIZE) -> SliceDecision:
    """Pick the dense mode + chunk count that fits ``memory_budget``.

    Rule: among all dense modes, take the one needing the FEWEST chunks
    (fewest extra passes over the sparse operand — the Ahrens et al.
    asymptotic model's first-order term); break ties toward output modes
    (streamed slabs, no accumulation pass), then toward the larger mode
    (more future headroom), then lexicographically.  Raises
    :class:`MemoryBudgetError` when no single-mode chunking can fit —
    callers should shard the tensor (distributed replay) instead.

    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> spec = S.mttkrp(64, 32, 16, 64)
    >>> p = plan(spec)
    >>> levels = {0: 1, 1: 64, 2: 512, 3: 2048}
    >>> d = choose_slicing(spec, p.path, p.order, levels,
    ...                    memory_budget=300_000)
    >>> (d.mode, d.chunks, d.kind)       # fits: nothing to slice
    (None, 1, 'none')
    >>> d = choose_slicing(spec, p.path, p.order, levels,
    ...                    memory_budget=150_000)
    >>> (d.mode, d.kind, d.chunks > 1, d.chunk_bytes <= 150_000)
    ('a', 'output', True, True)
    """
    if memory_budget <= 0:
        raise ValueError(f"memory_budget must be positive bytes, got "
                         f"{memory_budget!r}")
    levels = (dict(nnz_levels) if nnz_levels is not None
              else _default_nnz_levels(spec))
    base = _footprint(spec, path, order, levels, spec.dims, itemsize)
    if base <= memory_budget:
        return SliceDecision(mode=None, chunks=1, kind="none",
                             peak_bytes=base, chunk_bytes=base)

    sp = set(spec.sparse_indices)
    out = set(spec.output.indices)
    best = None
    for mode in spec.all_indices:
        if mode in sp or spec.dims[mode] < 2:
            continue
        chunks = _min_chunks(spec, path, order, levels, memory_budget,
                             mode, itemsize)
        if chunks is None:
            continue
        kind = "output" if mode in out else "contracted"
        rank = (chunks, 0 if kind == "output" else 1,
                -spec.dims[mode], mode)
        if best is None or rank < best[0]:
            best = (rank, mode, chunks, kind)
    if best is None:
        raise MemoryBudgetError(
            f"plan working set is {base} bytes and no single dense-mode "
            f"chunking fits memory_budget={memory_budget}; shard the "
            "sparse tensor (see docs/distributed.md) or raise the budget")
    _, mode, chunks, kind = best
    dims = dict(spec.dims)
    dims[mode] = _chunk_width(spec.dims[mode], chunks)
    cb = _footprint(spec, path, order, levels, dims, itemsize)
    return SliceDecision(mode=mode, chunks=chunks, kind=kind,
                         peak_bytes=base, chunk_bytes=cb)


def stamp_plan_slicing(plan, nnz_levels: Mapping[int, int] | None,
                       memory_budget: int | None,
                       itemsize: int = DEFAULT_ITEMSIZE):
    """Return ``plan`` with ``slice_mode``/``slice_chunks`` set for
    ``memory_budget`` (or cleared when it fits / budget is None).  Pure —
    the input plan is never mutated, so a cached instance stays unsliced."""
    if memory_budget is None:
        return plan
    d = choose_slicing(plan.spec, plan.path, plan.order, nnz_levels,
                       memory_budget, itemsize=itemsize)
    if (plan.slice_mode, plan.slice_chunks) == (d.mode, d.chunks):
        return plan
    return dataclasses.replace(plan, slice_mode=d.mode,
                               slice_chunks=d.chunks)


def plan_decision(plan, nnz_levels: Mapping[int, int] | None = None,
                  itemsize: int = DEFAULT_ITEMSIZE) -> SliceDecision:
    """Reconstruct the :class:`SliceDecision` a stamped plan encodes
    (footprints re-priced from the profile) — what benchmarks assert."""
    spec = plan.spec
    levels = (dict(nnz_levels) if nnz_levels is not None
              else _default_nnz_levels(spec))
    base = _footprint(spec, plan.path, plan.order, levels, spec.dims,
                      itemsize)
    mode, chunks = plan.slice_mode, plan.slice_chunks
    if mode is None:
        return SliceDecision(mode=None, chunks=1, kind="none",
                             peak_bytes=base, chunk_bytes=base)
    dims = dict(spec.dims)
    dims[mode] = _chunk_width(spec.dims[mode], chunks)
    cb = _footprint(spec, plan.path, plan.order, levels, dims, itemsize)
    kind = ("output" if mode in set(spec.output.indices) else "contracted")
    return SliceDecision(mode=mode, chunks=chunks, kind=kind,
                         peak_bytes=base, chunk_bytes=cb)


def chunk_footprints(plan, nnz_levels: Mapping[int, int] | None = None,
                     itemsize: int = DEFAULT_ITEMSIZE) -> list[int]:
    """Per-chunk working-set bytes of a stamped plan, tail included —
    every entry must be ``<= memory_budget`` for the stamping budget."""
    spec = plan.spec
    levels = (dict(nnz_levels) if nnz_levels is not None
              else _default_nnz_levels(spec))
    mode, chunks = plan.slice_mode, plan.slice_chunks
    if mode is None:
        return [_footprint(spec, plan.path, plan.order, levels, spec.dims,
                           itemsize)]
    D = spec.dims[mode]
    width = _chunk_width(D, chunks)
    out = []
    for start in range(0, D, width):
        dims = dict(spec.dims)
        dims[mode] = min(width, D - start)
        out.append(_footprint(spec, plan.path, plan.order, levels, dims,
                              itemsize))
    return out


# --------------------------------------------------------------------------- #
# Sliced replay
# --------------------------------------------------------------------------- #
def sliced_execute(plan, csf, factors: Mapping, backend: str | None = None,
                   mode: str | None = None, chunks: int | None = None,
                   executor_cache: dict | None = None, **kwargs):
    """Replay one tuned plan per chunk of its sliced dense mode.

    ``mode``/``chunks`` default to the plan's stamped ``slice_mode``/
    ``slice_chunks``.  Factors carrying the mode are restricted to the
    chunk's index range; the CSF operand is untouched (dense modes never
    enter the sparse pattern).  Output-mode partials are disjoint slabs
    written into the full result; contracted-mode partials are accumulated
    in float64 and cast back.  ``executor_cache`` (chunk width -> engine)
    lets serving loops reuse compiled chunk executors across requests.
    Extra kwargs reach :func:`repro.core.executor.make_executor`.
    """
    from repro.core import executor as X
    spec = plan.spec
    mode = mode if mode is not None else plan.slice_mode
    chunks = chunks if chunks is not None else plan.slice_chunks
    if mode is None or chunks <= 1:
        raise ValueError("sliced_execute needs a sliced plan: slice_mode "
                         "is None / slice_chunks <= 1 (use execute_plan)")
    # slice-mode kind legality lives in the verifier (SPTTN-E030/E031);
    # chunk range is checked below against the actual chunking math
    from repro.analysis.invariants import check_slice
    for d in check_slice(spec, mode, None):
        raise ValueError(f"{d.message} [{d.code}]")

    D = spec.dims[mode]
    width = _chunk_width(D, max(1, min(chunks, D)))
    resolved = backend or plan.backend
    if resolved in PALLAS_BACKENDS:
        if getattr(plan, "fused", False):
            kwargs.setdefault("strategy", "fused")
        if getattr(plan, "block", None):
            kwargs.setdefault("block", plan.block)

    arrays = csf if isinstance(csf, X.CSFArrays) else X.CSFArrays.from_csf(csf)
    by_name = {t.name: t for t in spec.inputs}
    out_ax = (spec.output.indices.index(mode)
              if mode in spec.output.indices else None)
    executor_cache = executor_cache if executor_cache is not None else {}

    full = None      # output-mode: assembled result
    acc = None       # contracted-mode: float64 accumulator
    out_dtype = None
    for start in range(0, D, width):
        w = min(width, D - start)
        ex = executor_cache.get(w)
        if ex is None:
            dims_c = dict(spec.dims)
            dims_c[mode] = w
            spec_c = dataclasses.replace(spec, dims=dims_c)
            ex = X.make_executor(spec_c, plan.path, plan.order,
                                 backend=resolved, **kwargs)
            executor_cache[w] = ex
        f_c = {}
        for name, arr in factors.items():
            t = by_name.get(name)
            if t is not None and not t.is_sparse and mode in t.indices:
                sl = [slice(None)] * np.ndim(arr)
                sl[t.indices.index(mode)] = slice(start, start + w)
                arr = arr[tuple(sl)]
            f_c[name] = arr
        part = np.asarray(ex(arrays, f_c))
        out_dtype = part.dtype
        if out_ax is not None:
            if full is None:
                shape = list(part.shape)
                shape[out_ax] = D
                full = np.zeros(shape, dtype=part.dtype)
            sl = [slice(None)] * part.ndim
            sl[out_ax] = slice(start, start + w)
            full[tuple(sl)] = part
        else:
            p64 = part.astype(np.float64)
            acc = p64 if acc is None else acc + p64
    result = full if out_ax is not None else acc.astype(out_dtype)
    import jax.numpy as jnp
    return jnp.asarray(result)
