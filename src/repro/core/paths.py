"""Contraction-path enumeration (paper §4.1.1, Def 4.1).

A contraction path for N+1 tensors is a depth-first post-ordering of a binary
contraction tree: a sequence of N *terms*, each contracting two operands
(inputs or intermediates) into an output operand.  The recurrence
``T(n) = C(n,2) * T(n-1)`` with ``T(2) = 1`` counts ordered paths, i.e.
``T(n) = (n!)^2 / (n * 2^(n-1))`` (paper reports the same up to O-constants).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections.abc import Iterator, Sequence

from repro.core.spec import SpTTNSpec, TensorRef


@dataclasses.dataclass(frozen=True)
class Operand:
    """An operand of a contraction term (input tensor or intermediate)."""

    name: str
    indices: tuple[str, ...]
    is_sparse: bool = False

    def __str__(self) -> str:  # pragma: no cover
        star = "*" if self.is_sparse else ""
        return f"{self.name}{star}({','.join(self.indices)})"


@dataclasses.dataclass(frozen=True)
class Term:
    """One pairwise contraction ``lhs * rhs -> out`` (a leaf of a loop nest)."""

    lhs: Operand
    rhs: Operand
    out: Operand

    @property
    def indices(self) -> tuple[str, ...]:
        """All indices of the term, sparse (storage order) before dense."""
        seen: list[str] = []
        for op in (self.lhs, self.rhs, self.out):
            for i in op.indices:
                if i not in seen:
                    seen.append(i)
        return tuple(seen)

    @property
    def index_set(self) -> frozenset[str]:
        return frozenset(self.indices)

    @property
    def is_sparse(self) -> bool:
        return self.lhs.is_sparse or self.rhs.is_sparse

    @property
    def contracted(self) -> tuple[str, ...]:
        out = set(self.out.indices)
        return tuple(i for i in self.indices if i not in out)

    def __str__(self) -> str:  # pragma: no cover
        return f"{self.lhs} . {self.rhs} -> {self.out}"


ContractionPath = tuple[Term, ...]


def _operand_of(t: TensorRef) -> Operand:
    return Operand(name=t.name, indices=t.indices, is_sparse=t.is_sparse)


def _intermediate(spec: SpTTNSpec, a: Operand, b: Operand,
                  remaining: Sequence[Operand]) -> Operand:
    """Build the output operand of contracting ``a . b``.

    Kept indices = indices needed by any remaining operand or the final
    output.  Index order: sparse indices in CSF storage order first, then
    dense indices in spec order (canonical; executor relies on it).
    """
    needed: set[str] = set(spec.output.indices)
    for op in remaining:
        needed |= set(op.indices)
    mine = set(a.indices) | set(b.indices)
    kept = mine & needed
    sparse_order = [i for i in spec.sparse_indices if i in kept]
    sp = set(spec.sparse_indices)
    dense_order = [i for i in spec.all_indices if i in kept and i not in sp]
    is_sparse = (a.is_sparse or b.is_sparse) and bool(sparse_order)
    name = f"({a.name}.{b.name})"
    return Operand(name=name, indices=tuple(sparse_order + dense_order),
                   is_sparse=is_sparse)


def enumerate_paths(spec: SpTTNSpec) -> Iterator[ContractionPath]:
    """Yield every ordered contraction path (paper §4.1.1)."""

    def rec(ops: tuple[Operand, ...],
            acc: tuple[Term, ...]) -> Iterator[ContractionPath]:
        if len(ops) == 1:
            yield acc
            return
        if len(ops) == 2:
            a, b = ops
            out = Operand(name="OUT", indices=spec.output.indices,
                          is_sparse=spec.output_is_sparse)
            yield acc + (Term(lhs=a, rhs=b, out=out),)
            return
        for ia, ib in itertools.combinations(range(len(ops)), 2):
            a, b = ops[ia], ops[ib]
            rest = tuple(o for j, o in enumerate(ops) if j not in (ia, ib))
            out = _intermediate(spec, a, b, rest)
            term = Term(lhs=a, rhs=b, out=out)
            yield from rec(rest + (out,), acc + (term,))

    yield from rec(tuple(_operand_of(t) for t in spec.inputs), ())


def path_depth(path: ContractionPath) -> int:
    """Max loop-nest depth over terms (= paper's asymptotic-complexity proxy)."""
    return max(len(t.indices) for t in path)


def count_paths(n: int) -> int:
    """Closed form of the recurrence T(n) = C(n,2) T(n-1), T(2) = 1."""
    c = 1
    for k in range(3, n + 1):
        c *= k * (k - 1) // 2
    return c


def consumer_map(path: ContractionPath) -> dict[int, int]:
    """Map producer term index -> consumer term index (binary-tree edges).

    The final term's output is the kernel output and has no consumer.
    """
    out: dict[int, int] = {}
    for i, t in enumerate(path):
        for j in range(i + 1, len(path)):
            if path[j].lhs.name == t.out.name or path[j].rhs.name == t.out.name:
                out[i] = j
                break
    return out


def min_depth_paths(spec: SpTTNSpec,
                    max_paths: int | None = None,
                    slack: int = 0) -> list[ContractionPath]:
    """All paths whose depth is within ``slack`` of the minimum (paper §5:
    'considers all contraction paths with optimal asymptotic complexity')."""
    best: int | None = None
    kept: list[tuple[int, ContractionPath]] = []
    for p in enumerate_paths(spec):
        d = path_depth(p)
        if best is None or d < best:
            best = d
            kept = [(dd, pp) for dd, pp in kept if dd <= best + slack]
        if d <= best + slack:
            kept.append((d, p))
            if max_paths is not None and len(kept) > 4 * max_paths:
                kept.sort(key=lambda x: x[0])
                kept = kept[:2 * max_paths]
    kept = [pp for dd, pp in kept if dd <= best + slack]
    # dedupe identical term sequences (paths can coincide after reordering)
    seen: set[str] = set()
    uniq: list[ContractionPath] = []
    for p in kept:
        key = "|".join(str(t) for t in p)
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    if max_paths is not None:
        uniq = uniq[:max_paths]
    return uniq
