"""Loop orders, peeling, and fully-fused loop-nest forests (Defs 4.2-4.5).

A *loop order* ``A = (A_1, ..., A_N)`` assigns each contraction term a
permutation of its indices.  The corresponding fully-fused loop-nest forest
is built by iterative *peeling*: consecutive terms sharing the same leading
index fuse under a single loop vertex (Def 4.4).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections.abc import Iterator, Sequence

from repro.core.paths import ContractionPath, Term, consumer_map

LoopOrder = tuple[tuple[str, ...], ...]  # one index tuple per term


# --------------------------------------------------------------------------- #
# Forest construction (Def 4.4)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class LoopNode:
    """A loop vertex; children are nested loops or term leaves."""

    index: str
    children: list["LoopNode | TermLeaf"] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class TermLeaf:
    """A contraction-term leaf of the loop-nest forest."""

    term_id: int


Forest = list["LoopNode | TermLeaf"]


def build_forest(order: LoopOrder) -> Forest:
    """Construct the fully-fused loop nest forest from a loop order.

    Implements Def 4.4 via iterative peeling: group consecutive terms whose
    remaining order starts with the same index.
    """

    def rec(seq: list[tuple[int, tuple[str, ...]]]) -> Forest:
        forest: Forest = []
        i = 0
        while i < len(seq):
            tid, rem = seq[i]
            if not rem:
                forest.append(TermLeaf(term_id=tid))
                i += 1
                continue
            q = rem[0]
            group: list[tuple[int, tuple[str, ...]]] = []
            j = i
            while j < len(seq) and seq[j][1] and seq[j][1][0] == q:
                group.append((seq[j][0], seq[j][1][1:]))
                j += 1
            forest.append(LoopNode(index=q, children=rec(group)))
            i = j
        return forest

    return rec([(i, a) for i, a in enumerate(order)])


def leaf_paths(forest: Forest) -> dict[int, tuple[str, ...]]:
    """Root-to-leaf loop-index path for every term leaf."""
    out: dict[int, tuple[str, ...]] = {}

    def rec(f: Forest, prefix: tuple[str, ...]) -> None:
        for node in f:
            if isinstance(node, TermLeaf):
                out[node.term_id] = prefix
            else:
                rec(node.children, prefix + (node.index,))

    rec(forest, ())
    return out


def leaf_vertex_paths(forest: Forest) -> dict[int, tuple[tuple[int, str], ...]]:
    """Root-to-leaf path as (vertex_id, index) pairs.  Vertex identity
    matters: two same-labelled loops separated by a sibling are DIFFERENT
    vertices and share no iterations (they are not common ancestors)."""
    out: dict[int, tuple[tuple[int, str], ...]] = {}
    counter = [0]

    def rec(f: Forest, prefix) -> None:
        for node in f:
            if isinstance(node, TermLeaf):
                out[node.term_id] = prefix
            else:
                vid = counter[0]
                counter[0] += 1
                rec(node.children, prefix + ((vid, node.index),))

    rec(forest, ())
    return out


def common_ancestor_indices(path_u, path_v) -> set[str]:
    """Loop indices of the true common ancestors (vertex-id LCA prefix)."""
    anc = set()
    for (ida, ia), (idb, _) in zip(path_u, path_v):
        if ida != idb:
            break
        anc.add(ia)
    return anc


# --------------------------------------------------------------------------- #
# Validity and enumeration of loop orders
# --------------------------------------------------------------------------- #
def is_valid_order(path: ContractionPath, order: LoopOrder,
                   sparse_storage: Sequence[str] = ()) -> bool:
    """An order is valid iff each A_i is a permutation of term i's indices
    and (framework restriction, paper §5) every term iterates its sparse
    indices in CSF storage order."""
    if len(order) != len(path):
        return False
    pos = {s: i for i, s in enumerate(sparse_storage)}
    for term, a in zip(path, order):
        if sorted(a) != sorted(term.indices):
            return False
        sp = [i for i in a if i in pos]
        if any(pos[x] > pos[y] for x, y in zip(sp, sp[1:])):
            return False
    return True


def enumerate_orders(path: ContractionPath,
                     sparse_storage: Sequence[str] = ()
                     ) -> Iterator[LoopOrder]:
    """Exhaustively enumerate valid loop orders (paper §4.1.2).

    Cardinality is prod_i |I_i|! / k_i! once the sparse-order restriction is
    applied (k_i = number of sparse indices in term i).
    """
    pos = {s: i for i, s in enumerate(sparse_storage)}

    def term_orders(term: Term) -> Iterator[tuple[str, ...]]:
        for perm in itertools.permutations(term.indices):
            sp = [i for i in perm if i in pos]
            if all(pos[x] <= pos[y] for x, y in zip(sp, sp[1:])):
                yield perm

    for combo in itertools.product(*[list(term_orders(t)) for t in path]):
        yield tuple(combo)


# --------------------------------------------------------------------------- #
# Intermediate buffers (Eq. 7)
# --------------------------------------------------------------------------- #
def buffer_indices(path: ContractionPath, order: LoopOrder
                   ) -> dict[int, tuple[str, ...]]:
    """Indices of each intermediate buffer under the fused forest.

    Buffer between producer term u and its consumer v:
      inds = out(u) \\ common_ancestors(u, v)           (Eq. 7)
    where common ancestors are determined by vertex identity (LCA), not by
    loop labels.  The final term's output is the kernel output, not a
    buffer.
    """
    forest = build_forest(order)
    paths_ = leaf_vertex_paths(forest)
    cons = consumer_map(path)
    out: dict[int, tuple[str, ...]] = {}
    for u, v in cons.items():
        anc = common_ancestor_indices(paths_[u], paths_[v])
        out[u] = tuple(i for i in path[u].out.indices if i not in anc)
    return out


def fused_sparse_depth(path: ContractionPath, order: LoopOrder,
                       sparse_storage: Sequence[str]) -> dict[int, int]:
    """For each buffer, the number of sparse loops among the true common
    ancestors (= the CSF level at which the vectorized executor
    materializes it)."""
    forest = build_forest(order)
    paths_ = leaf_vertex_paths(forest)
    cons = consumer_map(path)
    sp = set(sparse_storage)
    depth: dict[int, int] = {}
    for u, v in cons.items():
        anc = common_ancestor_indices(paths_[u], paths_[v])
        depth[u] = sum(1 for i in anc if i in sp)
    return depth
