"""Structured diagnostics for the static plan verifier.

Every legality fact the engines used to discover one at a time — a bare
bool from ``fusible_chains``, a ``ValueError`` three frames inside a
Pallas lowering — is reported here as a :class:`Diagnostic` with a
stable code, so callers (the tuner, CI, a user staring at a rejected
plan) can react to *which* invariant failed rather than parsing message
text.

Codes are namespaced ``SPTTN-<severity letter><number>``:

* ``SPTTN-Exxx`` — **errors**: the plan violates an invariant some
  engine enforces; executing it would raise (or worse, compute garbage).
* ``SPTTN-Wxxx`` — **warnings**: the plan is legal everywhere but some
  axis looks unprofitable or risky (e.g. an estimated VMEM overflow on
  real hardware); execution proceeds.

The registry :data:`DIAGNOSTIC_CODES` is the single source of truth for
the code table in ``docs/analysis.md`` (a test asserts the two agree).
"""
from __future__ import annotations

import dataclasses

ERROR = "error"
WARNING = "warning"

#: The execution-engine vocabulary.  Lives here — the leaf module of the
#: whole package graph — so both the verifier and ``core.executor``'s
#: dispatch share one tuple without an import cycle.
BACKENDS = ("reference", "xla", "pallas", "pallas-gpu")

#: The subset of :data:`BACKENDS` that lowers through the Pallas code
#: generator and therefore shares its engine kwargs (``block``,
#: ``strategy``, ``tile_align``), its fused/block plan axes, and its
#: stage-lowering registry.  Dispatch sites test membership here instead
#: of ``== "pallas"`` so a new Pallas target is one tuple entry, not a
#: grep over the codebase.
PALLAS_BACKENDS = ("pallas", "pallas-gpu")

#: backend -> stage-lowering target in the kernels/codegen registry
#: (``repro.kernels.codegen.ir``).  A backend listed here with no
#: registered lowering on the current host is SPTTN-E041.
PALLAS_TARGETS = {"pallas": "tpu", "pallas-gpu": "gpu"}

#: backend -> the ``jax.default_backend()`` device kind it compiles for.
#: Interpret mode runs anywhere (that is the CPU witness convention);
#: compiled mode on a different device kind is SPTTN-W005.
BACKEND_DEVICE_KINDS = {"pallas": "tpu", "pallas-gpu": "gpu"}

#: code -> one-line summary.  Append-only: codes are stable identifiers
#: (CI batteries and user scripts match on them), so a retired invariant
#: keeps its number reserved rather than renumbering the rest.
DIAGNOSTIC_CODES: dict[str, str] = {
    "SPTTN-E001": "storage-prefix violation: sparse indices out of CSF "
                  "storage order in a term's loop order",
    "SPTTN-E002": "loop order is not a permutation of its term's indices",
    "SPTTN-E003": "loop order length does not match contraction path length",
    "SPTTN-E004": "path's final term does not produce the spec output",
    "SPTTN-E010": "fused requested but the path has no provably safe "
                  "reducing chain",
    "SPTTN-E011": "fused-chain levels not strictly descending along the "
                  "CSF path",
    "SPTTN-E012": "fused-chain link operand not a dense input",
    "SPTTN-E013": "fused-chain consumer is not the next path term",
    "SPTTN-E020": "block is not a positive integer",
    "SPTTN-E021": "block is not a multiple of the TPU sublane (8)",
    "SPTTN-E022": "padded operand length is not a multiple of the block "
                  "(tile grid would drop tail slots)",
    "SPTTN-E030": "slice mode not in spec dims",
    "SPTTN-E031": "slice mode is a sparse index (sparse modes shard, "
                  "never slice)",
    "SPTTN-E032": "slice chunk count out of range for the sliced dim",
    "SPTTN-E033": "slice chunks > 1 with no slice mode",
    "SPTTN-E040": "unknown backend",
    "SPTTN-E041": "backend has no registered stage lowering on this host "
                  "(plan replayed where its per-target lowering is "
                  "unavailable)",
    "SPTTN-E050": "mesh context malformed",
    "SPTTN-E051": "plan not stackable: a sparse-structured stage has no "
                  "same-level zero-on-pads operand",
    "SPTTN-E052": "same-sparsity output on a distributed path (needs the "
                  "stacked layout to reassemble leaf values)",
    "SPTTN-E060": "plan JSON version mismatch (re-plan, never guess)",
    "SPTTN-W003": "estimated VMEM scratch exceeds budget estimate",
    "SPTTN-W004": "dtype promotion widens a crossing buffer",
    "SPTTN-W005": "plan backend compiles for a different device kind than "
                  "the current host (interpret-mode validation only)",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verdict of the static verifier.

    ``stage_ref`` localizes the finding — ``"term[2]"``, ``"order[0]"``,
    ``"plan.block"``, ``"chain[1..3]"`` — so a diagnostic can be mapped
    back onto the plan axis or path position that caused it without
    re-running the analysis.
    """

    code: str
    severity: str       # ERROR | WARNING
    stage_ref: str
    message: str
    fix_hint: str = ""

    def __post_init__(self) -> None:
        if self.code not in DIAGNOSTIC_CODES:
            raise ValueError(f"unregistered diagnostic code {self.code!r}")

    def __str__(self) -> str:
        s = f"{self.code} [{self.severity}] {self.stage_ref}: {self.message}"
        if self.fix_hint:
            s += f" (fix: {self.fix_hint})"
        return s


def diag(code: str, stage_ref: str, message: str,
         fix_hint: str = "") -> Diagnostic:
    """Build a :class:`Diagnostic`, deriving severity from the code letter
    (``SPTTN-E...`` -> error, ``SPTTN-W...`` -> warning)."""
    severity = ERROR if code.startswith("SPTTN-E") else WARNING
    return Diagnostic(code=code, severity=severity, stage_ref=stage_ref,
                      message=message, fix_hint=fix_hint)


class PlanVerificationError(ValueError):
    """Raised by :meth:`PlanReport.raise_if_error`; carries the report."""

    def __init__(self, message: str, report: "PlanReport"):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """The verifier's full verdict on one plan: every diagnostic found,
    in path order, errors and warnings interleaved where they occurred."""

    diagnostics: tuple[Diagnostic, ...] = ()

    @property
    def errors(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == WARNING)

    @property
    def codes(self) -> tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)

    @property
    def ok(self) -> bool:
        """True when no error-severity diagnostic was found (warnings do
        not block execution)."""
        return not self.errors

    def __bool__(self) -> bool:
        return self.ok

    def raise_if_error(self, who: str = "verify_plan") -> "PlanReport":
        """Raise :class:`PlanVerificationError` listing every error
        diagnostic; return ``self`` unchanged when the plan is legal."""
        errs = self.errors
        if errs:
            lines = "; ".join(str(d) for d in errs)
            raise PlanVerificationError(
                f"{who}: plan rejected by static verification — {lines}",
                self)
        return self
