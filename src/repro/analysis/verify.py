"""``verify_plan`` — the one static pre-flight every consumer calls.

Orchestrates the invariant checkers in :mod:`repro.analysis.invariants`
over a plan (or raw ``(spec, path, order)`` plus axes) and returns a
:class:`~repro.analysis.diagnostics.PlanReport`.  Wired as a pre-flight
in ``execute_plan``, the autotuner (pruning E-severity candidates before
they are ever measured), ``make_distributed_tuned``, and
``serve.PlanService`` — and exposed on the facade as
``repro.verify_plan`` for users who want the verdict without running
anything.
"""
from __future__ import annotations

from collections.abc import Mapping

from repro.analysis import invariants as inv
from repro.analysis.diagnostics import (PALLAS_BACKENDS, Diagnostic,
                                        PlanReport)

_UNSET = object()


def verify_plan(plan_or_spec, path=None, order=None, *,
                backend=_UNSET, fused=_UNSET, block=_UNSET,
                slice_mode=_UNSET, slice_chunks=_UNSET, mesh=_UNSET,
                stacked: bool = False,
                dtypes: Mapping[str, str] | None = None,
                device_kind: str | None = None,
                vmem_budget: int = inv.DEFAULT_VMEM_BUDGET) -> PlanReport:
    """Statically verify a loop-nest schedule against every invariant the
    engines enforce, before anything compiles or runs.

    Two call shapes:

    * ``verify_plan(plan)`` — an :class:`~repro.core.planner.SpTTNPlan`;
      the plan's own axes (backend/fused/block/slice/mesh) are checked.
      Keyword arguments override individual axes.
    * ``verify_plan(spec, path, order, backend=..., ...)`` — raw
      schedule pieces, e.g. a tuner candidate before it exists as a plan.

    ``stacked=True`` additionally requires the zero-on-pads induction of
    the stacked shard_map Pallas engine (DESIGN.md §7).  ``dtypes`` (name
    -> dtype string) enables the crossing-buffer promotion analysis.
    ``device_kind`` (e.g. ``jax.default_backend()``) enables the
    backend/device-kind mismatch warning (SPTTN-W005) — omitted by
    default because interpret-mode validation off-device is this repo's
    standing convention, not a defect.

    Returns a :class:`PlanReport`; ``report.ok`` is True iff no
    error-severity diagnostic fired — exactly the plans the engines
    accept.  Warnings (W-codes) never block execution.

    >>> from repro.core import spec as S
    >>> from repro.core.planner import plan
    >>> p = plan(S.mttkrp(8, 6, 5, 4))
    >>> verify_plan(p).ok
    True
    >>> import dataclasses
    >>> bad = dataclasses.replace(p, slice_mode="i", slice_chunks=4)
    >>> verify_plan(bad).codes
    ('SPTTN-E031',)
    """
    if path is None and hasattr(plan_or_spec, "spec"):
        plan = plan_or_spec
        spec, path, order = plan.spec, plan.path, plan.order
        if backend is _UNSET:
            backend = plan.backend
        if fused is _UNSET:
            fused = getattr(plan, "fused", False)
        if block is _UNSET:
            block = getattr(plan, "block", None)
        if slice_mode is _UNSET:
            slice_mode = getattr(plan, "slice_mode", None)
        if slice_chunks is _UNSET:
            slice_chunks = getattr(plan, "slice_chunks", 1)
        if mesh is _UNSET:
            mesh = getattr(plan, "mesh", None)
    else:
        spec = plan_or_spec
        if path is None or order is None:
            raise TypeError("verify_plan needs an SpTTNPlan or "
                            "(spec, path, order)")
        backend = "xla" if backend is _UNSET else backend
        fused = False if fused is _UNSET else fused
        block = None if block is _UNSET else block
        slice_mode = None if slice_mode is _UNSET else slice_mode
        slice_chunks = 1 if slice_chunks is _UNSET else slice_chunks
        mesh = None if mesh is _UNSET else mesh

    diags: list[Diagnostic] = []
    diags += inv.check_backend(backend)
    diags += inv.check_lowering(backend)
    diags += inv.check_device_kind(backend, device_kind)
    diags += inv.check_path_output(spec, path)
    diags += inv.check_order(spec, path, order)
    if fused:
        diags += inv.chain_diagnostics(spec, path)
    diags += inv.check_block(block)
    diags += inv.check_slice(spec, slice_mode, slice_chunks)
    diags += inv.check_mesh(mesh)
    if stacked:
        diags += inv.stackable_diagnostics(spec, path, fused=bool(fused))
    if backend in PALLAS_BACKENDS:
        diags += inv.vmem_diagnostics(spec, path, block=block,
                                      budget=vmem_budget)
    diags += inv.dtype_diagnostics(spec, path, dtypes)
    return PlanReport(diagnostics=tuple(diags))
