"""The paper's loop-nest invariants, re-derived symbolically.

This module is the **single source of truth** for every legality fact
the engines enforce.  Each invariant is a pure function of the spec, the
contraction path, and the plan axes — no CSF operand, no jax — so the
verifier can run before any kernel is built, and the engines' own
guards (`fusible_chains` in kernels/codegen, `stackable_plan` in
distributed, `_check_block_grid` in the tile pass, the slice validators
in core) are thin wrappers over the functions here.

Checker functions return ``list[Diagnostic]`` (empty = invariant holds);
:func:`check_block_grid` returns ``Diagnostic | None`` for its single
fact.  :mod:`repro.analysis.verify` orchestrates them into one report.
"""
from __future__ import annotations

from collections.abc import Callable, Mapping, Sequence

from repro.analysis.diagnostics import (BACKEND_DEVICE_KINDS, BACKENDS,
                                        PALLAS_TARGETS, Diagnostic, diag)
from repro.core.paths import ContractionPath, consumer_map
from repro.core.spec import SpTTNSpec

#: Coarse per-core VMEM budget for the W003 scratch estimate (TPU v4/v5
#: order of magnitude; the estimate is advisory — real occupancy is the
#: compiler's call).
DEFAULT_VMEM_BUDGET = 16 * 2**20

_LANE = 128       # TPU lane width: last-dim padding unit
_SUBLANE = 8      # TPU sublane: block sizes must be multiples of this


# --------------------------------------------------------------------------- #
# Shared CSF-structure helpers (the storage-prefix vocabulary)
# --------------------------------------------------------------------------- #
def _spos(spec: SpTTNSpec) -> dict[str, int]:
    return {s: i for i, s in enumerate(spec.sparse_indices)}


def _slv(spos: Mapping[str, int], inds: Sequence[str]) -> int:
    """Deepest CSF level touched by ``inds`` (0 = fully dense)."""
    return max((spos[i] + 1 for i in inds if i in spos), default=0)


def _is_prefix(spos: Mapping[str, int], inds: Sequence[str]) -> bool:
    """True when the sparse indices in ``inds`` form a storage-order
    prefix of the CSF path (the paper's storage-prefix rule)."""
    sp = sorted(spos[i] for i in inds if i in spos)
    return sp == list(range(len(sp)))


def _reducing(spec: SpTTNSpec, spos: Mapping[str, int], term) -> bool:
    """A term the fused-chain lowering can host: touches the sparse
    operand, keeps storage-prefix on both sides, and strictly descends
    the CSF level from operand to output."""
    return (any(i in spos for i in term.indices)
            and _is_prefix(spos, term.indices)
            and _is_prefix(spos, term.out.indices)
            and _slv(spos, term.out.indices) < _slv(spos, term.indices))


# --------------------------------------------------------------------------- #
# Fused-chain legality (DESIGN.md §6)
# --------------------------------------------------------------------------- #
def fusible_chains(spec: SpTTNSpec,
                   path: ContractionPath) -> dict[int, tuple[int, ...]]:
    """Detect chains of reducing terms the fused-chain lowering can prove
    safe (DESIGN.md §6): maximal runs of *consecutive* path terms where
    each term's output is consumed by exactly the next term, every term
    reduces along the sparse operand's CSF path (storage-prefix indices,
    strictly decreasing output level, the consumer contracting at exactly
    the intermediate's level), and each non-first term's other operand is
    an original dense input (liftable onto that level's fibers without
    further recursion).  Returns ``{start_tid: (tid, ...)}`` for chains of
    length >= 2; everything else stays on the staged per-term path.

    Structural only — no CSF needed — so the autotuner can use it to
    decide whether ``fused`` is a meaningful candidate axis for a
    schedule before any operand exists.
    """
    spos = _spos(spec)
    dense_inputs = {t.name for t in spec.inputs if not t.is_sparse}

    cons = consumer_map(path)
    chains: dict[int, tuple[int, ...]] = {}
    used: set[int] = set()
    for t in range(len(path)):
        if t in used or not _reducing(spec, spos, path[t]):
            continue
        tids = [t]
        k = t
        while k + 1 < len(path) and cons.get(k) == k + 1:
            nxt = path[k + 1]
            inter = path[k].out.name
            other = (nxt.rhs if nxt.lhs.name == inter
                     else nxt.lhs if nxt.rhs.name == inter else None)
            if (other is None or other.name not in dense_inputs
                    or not _reducing(spec, spos, nxt)
                    or _slv(spos, nxt.indices)
                    != _slv(spos, path[k].out.indices)):
                break
            tids.append(k + 1)
            k += 1
        if len(tids) > 1:
            chains[t] = tuple(tids)
            used.update(tids)
    return chains


def chain_diagnostics(spec: SpTTNSpec,
                      path: ContractionPath) -> list[Diagnostic]:
    """Explain a ``fused=True`` request: empty when at least one provably
    safe chain exists, otherwise E010 plus per-term detail on *why* every
    candidate chain broke (the inverse of :func:`fusible_chains`)."""
    if fusible_chains(spec, path):
        return []
    spos = _spos(spec)
    dense_inputs = {t.name for t in spec.inputs if not t.is_sparse}
    diags = [diag(
        "SPTTN-E010", "plan.fused",
        "fused requested but the path has no provably safe reducing "
        "chain (fusible_chains found none)",
        "drop fused, or re-plan — the tuner only offers fused when a "
        "chain exists")]
    cons = consumer_map(path)
    for t in range(len(path) - 1):
        if not _reducing(spec, spos, path[t]):
            continue
        if cons.get(t) != t + 1:
            diags.append(diag(
                "SPTTN-E013", f"term[{t}]",
                f"term {t}'s output is consumed by term {cons.get(t)!r}, "
                "not the next path term — chains must be consecutive"))
            continue
        nxt = path[t + 1]
        inter = path[t].out.name
        other = (nxt.rhs if nxt.lhs.name == inter
                 else nxt.lhs if nxt.rhs.name == inter else None)
        if other is None or other.name not in dense_inputs:
            diags.append(diag(
                "SPTTN-E012", f"term[{t + 1}]",
                f"chain link at term {t + 1} multiplies the intermediate "
                f"by {other.name if other is not None else '<missing>'!r}, "
                "which is not an original dense input"))
        elif (not _reducing(spec, spos, nxt)
              or _slv(spos, nxt.indices) != _slv(spos, path[t].out.indices)):
            diags.append(diag(
                "SPTTN-E011", f"term[{t + 1}]",
                f"chain levels not strictly descending: term {t + 1} "
                f"contracts at level {_slv(spos, nxt.indices)}, expected "
                f"exactly the intermediate's level "
                f"{_slv(spos, path[t].out.indices)}"))
    return diags


# --------------------------------------------------------------------------- #
# Loop-order legality (paper §4.1.2 / §5)
# --------------------------------------------------------------------------- #
def check_order(spec: SpTTNSpec, path: ContractionPath,
                order) -> list[Diagnostic]:
    """Per-term loop-order legality: one permutation per term, sparse
    indices in CSF storage order (the same facts as
    :func:`repro.core.loopnest.is_valid_order`, localized per term)."""
    if len(order) != len(path):
        return [diag(
            "SPTTN-E003", "plan.order",
            f"loop order has {len(order)} entries for {len(path)} path "
            "terms — found vs expected lengths must match")]
    spos = _spos(spec)
    diags: list[Diagnostic] = []
    for i, (term, a) in enumerate(zip(path, order)):
        if sorted(a) != sorted(term.indices):
            diags.append(diag(
                "SPTTN-E002", f"order[{i}]",
                f"order {tuple(a)!r} is not a permutation of term {i}'s "
                f"indices {tuple(term.indices)!r}"))
            continue
        sp = [x for x in a if x in spos]
        if any(spos[x] > spos[y] for x, y in zip(sp, sp[1:])):
            diags.append(diag(
                "SPTTN-E001", f"order[{i}]",
                f"sparse indices {tuple(sp)!r} in term {i}'s order "
                f"violate CSF storage order {spec.sparse_indices!r} "
                "(storage-prefix rule, paper §5)",
                "iterate the term's sparse indices in storage order"))
    return diags


def check_path_output(spec: SpTTNSpec,
                      path: ContractionPath) -> list[Diagnostic]:
    """The final term must produce exactly the spec output."""
    if not path or tuple(path[-1].out.indices) != tuple(spec.output.indices):
        found = tuple(path[-1].out.indices) if path else ()
        return [diag(
            "SPTTN-E004", f"term[{max(len(path) - 1, 0)}]",
            f"path's final term produces {found!r}, expected the spec "
            f"output {tuple(spec.output.indices)!r}")]
    return []


# --------------------------------------------------------------------------- #
# Plan-axis legality: backend / block / slice / mesh
# --------------------------------------------------------------------------- #
def check_backend(backend) -> list[Diagnostic]:
    if backend not in BACKENDS:
        return [diag(
            "SPTTN-E040", "plan.backend",
            f"unknown backend {backend!r}; expected one of {BACKENDS}")]
    return []


def check_lowering(backend) -> list[Diagnostic]:
    """A Pallas-family backend is executable only where its stage
    lowering target (:data:`PALLAS_TARGETS`) is registered in the
    kernels/codegen registry — a plan JSON replayed on a host whose
    build lacks the target must be rejected *before* the engine is
    constructed, not by an ``AttributeError`` three frames deep.  The
    registry import is lazy: this module is imported by the codegen
    executor itself, so a top-level import would cycle."""
    target = PALLAS_TARGETS.get(backend)
    if target is None:
        return []
    import repro.kernels.codegen  # registers the built-in lowerings
    from repro.kernels.codegen.ir import lowering_targets
    if target not in lowering_targets():
        return [diag(
            "SPTTN-E041", "plan.backend",
            f"backend {backend!r} needs stage lowering target "
            f"{target!r}, but this host registers only "
            f"{lowering_targets()}",
            "re-plan on this host (the tuner only emits backends it "
            "can lower) instead of replaying the foreign plan JSON")]
    return []


def check_device_kind(backend, device_kind) -> list[Diagnostic]:
    """Compiled Pallas kernels only run on the device kind their target
    compiles for (:data:`BACKEND_DEVICE_KINDS`); anywhere else the
    engines fall back to ``interpret=True`` validation semantics.  That
    is legal — it is this repo's CPU witness convention — but a serving
    deployment replaying a ``pallas-gpu`` winner on a TPU host is almost
    certainly a routing mistake, so the mismatch is a warning
    (SPTTN-W005), surfaced only when the caller states the host device
    kind explicitly."""
    want = BACKEND_DEVICE_KINDS.get(backend)
    if want is None or device_kind is None or device_kind == want:
        return []
    return [diag(
        "SPTTN-W005", "plan.backend",
        f"backend {backend!r} compiles for device kind {want!r} but the "
        f"host is {device_kind!r}; execution falls back to interpret-"
        "mode validation semantics",
        "tune on this host (the device kind is part of the cache key) "
        "or route the plan to a matching device")]


def check_block(block) -> list[Diagnostic]:
    """Tuned Pallas fiber block sizes are positive sublane multiples
    (DESIGN.md §8); ``None`` means engine default and is always legal."""
    if block is None:
        return []
    if not isinstance(block, int) or isinstance(block, bool) or block < 1:
        return [diag(
            "SPTTN-E020", "plan.block",
            f"block must be positive, got {block!r} — block sizes are "
            "positive multiples of 8")]
    if block % _SUBLANE:
        return [diag(
            "SPTTN-E021", "plan.block",
            f"block {block!r} is not a multiple of the TPU sublane "
            f"({_SUBLANE}) — tuned block sizes must be positive "
            "multiples of 8")]
    return []


def check_block_grid(padded_len: int, block: int) -> Diagnostic | None:
    """The sequential grid covers ``padded_len // block`` blocks; a
    non-multiple length would silently drop the tail slots."""
    if padded_len % block:
        return diag(
            "SPTTN-E022", "stage.grid",
            f"padded operand length {padded_len} is not a multiple of "
            f"the stage block {block}",
            "layout producers must pad to block multiples "
            "(padded_segment_layout / pad_segment_layout)")
    return None


def check_slice(spec: SpTTNSpec, mode, chunks) -> list[Diagnostic]:
    """Slice-mode kind legality (DESIGN.md §10): only a dense mode may be
    chunked — output-kind modes assemble disjoint slabs, contracted-kind
    modes accumulate in float64, sparse modes are *sharding*, never
    slicing."""
    if mode is None:
        if chunks is not None and chunks > 1:
            return [diag(
                "SPTTN-E033", "plan.slice_chunks",
                f"slice_chunks must be 1 when slice_mode is null, "
                f"got {chunks!r}")]
        return []
    if mode not in spec.dims:
        return [diag(
            "SPTTN-E030", "plan.slice_mode",
            f"slice mode {mode!r} not in spec dims "
            f"{tuple(spec.dims)!r}")]
    if mode in spec.sparse_indices:
        return [diag(
            "SPTTN-E031", "plan.slice_mode",
            f"slice mode {mode!r} is a sparse index; slicing sparse "
            "modes is nonzero sharding — only dense modes are sliceable",
            "pass a shard list to execute_plan instead")]
    if chunks is not None and (chunks < 2 or chunks > spec.dims[mode]):
        return [diag(
            "SPTTN-E032", "plan.slice_chunks",
            f"slice_chunks must be in [2, dims[{mode}]="
            f"{spec.dims[mode]}] when slice_mode is set, got {chunks!r}")]
    return []


def check_mesh(mesh) -> list[Diagnostic]:
    """Shard-context shape (``shard_mesh_key``): a mapping with
    ``mesh_shape``/``mode_axis`` sub-mappings and an integer ``shard``."""
    if mesh is None:
        return []
    if not isinstance(mesh, dict):
        return [diag(
            "SPTTN-E050", "plan.mesh",
            f"plan mesh must be an object or null, got {mesh!r}")]
    diags: list[Diagnostic] = []
    for key in ("mesh_shape", "mode_axis"):
        if key in mesh and not isinstance(mesh[key], dict):
            diags.append(diag(
                "SPTTN-E050", f"plan.mesh.{key}",
                f"plan mesh {key} must be an object, got {mesh[key]!r}"))
    if "shard" in mesh and (not isinstance(mesh["shard"], int)
                            or isinstance(mesh["shard"], bool)):
        diags.append(diag(
            "SPTTN-E050", "plan.mesh.shard",
            f"plan mesh shard must be an integer, got {mesh['shard']!r}"))
    return diags


# --------------------------------------------------------------------------- #
# Stackability: zero-on-pads induction (DESIGN.md §7)
# --------------------------------------------------------------------------- #
def plan_layout_walk(spec: SpTTNSpec, path, chains,
                     row_for: Callable[[int, int], bool]):
    """Mirror the executor dispatch host-side: walk the plan tracking
    which intermediates are FiberVals and at what CSF level, verify the
    stacked zero-nnz padding stays inert, and collect the block-layout
    requests the Pallas lowering will ask for at trace time.

    Returns ``(stackable, requests)``.  ``stackable`` is False when some
    sparse-structured stage has no operand that is provably zero on pad
    fibers at the stage's own level — e.g. a broadcast-down lift
    (``v.level < lvl``) would gather REAL ancestor rows onto pad fibers
    and pollute the result.  ``requests`` holds ``("stage", lvl,
    out_lvl)`` for row-lowered reductions and ``("chain", lvl0, levels)``
    for fused chains (segsum/product stages need no precomputed layout).
    ``row_for(lvl, out_lvl)`` is the executor's strategy choice;
    ``chains`` its detected fused chains (empty when not fused).
    """
    spos = _spos(spec)

    # name -> CSF level for every FiberVal intermediate; all tracked
    # entries are zero-on-pads by induction (a stage with a same-level
    # zero operand multiplies pads to zero, and the sorted pad-segment
    # tails reduce those zeros into the final row)
    fib_lvl = {spec.sparse_input.name: len(spec.sparse_indices)}
    requests: list[tuple] = []
    ok = True
    tid, n = 0, len(path)
    while tid < n:
        chain = chains.get(tid)
        if chain and len(chain) > 1:
            terms = [path[k] for k in chain]
            first = terms[0]
            lvl0 = _slv(spos, first.indices)
            levels = tuple(_slv(spos, t.out.indices) for t in terms)
            if not any(fib_lvl.get(o.name) == lvl0
                       for o in (first.lhs, first.rhs)):
                ok = False
            requests.append(("chain", lvl0, levels))
            last = terms[-1]
            if last.out.name != "OUT" and levels[-1] > 0:
                fib_lvl[last.out.name] = levels[-1]
            tid += len(chain)
            continue
        term = path[tid]
        tid += 1
        term_sp = any(i in spos for i in term.indices)
        lvl, out_lvl = _slv(spos, term.indices), _slv(spos, term.out.indices)
        fibs = [o.name for o in (term.lhs, term.rhs) if o.name in fib_lvl]
        prefix_ok = (_is_prefix(spos, term.indices)
                     and _is_prefix(spos, term.out.indices))
        is_final = term.out.name == "OUT"
        if term_sp and fibs and (prefix_ok
                                 or (is_final
                                     and _is_prefix(spos, term.indices))):
            # fiber path / final scatter: needs one same-level zero operand
            if not any(fib_lvl[nm] == lvl for nm in fibs):
                ok = False
            if prefix_ok:
                if out_lvl < lvl and row_for(lvl, out_lvl):
                    requests.append(("stage", lvl, out_lvl))
                if not is_final and out_lvl > 0:
                    fib_lvl[term.out.name] = out_lvl
            # the final-scatter product stage and segsum reductions use
            # no precomputed layout (coords/segs come straight from the
            # stacked CSF arrays)
        # else: dense fallback — densifying a tracked FiberVal scatters
        # zeros for pad fibers (zero-on-pads by induction), so it's safe
    return ok, requests


def stackable_diagnostics(spec: SpTTNSpec, path,
                          fused: bool = False) -> list[Diagnostic]:
    """Why (or that) a plan cannot ride the stacked shard_map Pallas
    engine; empty when it can."""
    if spec.output_is_sparse:
        return [diag(
            "SPTTN-E052", "spec.output",
            "same-sparsity (TTTP-like) output: the stacked/sharded path "
            "requires a dense output — per-shard leaf values cannot be "
            "summed",
            "use make_distributed's collective layout instead")]
    chains = fusible_chains(spec, path) if fused else {}
    ok, _ = plan_layout_walk(spec, path, chains,
                             lambda lvl, out_lvl: False)
    if not ok:
        return [diag(
            "SPTTN-E051", "plan",
            "plan is not stackable: a sparse-structured stage has no "
            "operand provably zero on pad fibers at its own CSF level",
            "per-shard replay handles it (make_distributed_tuned falls "
            "back automatically)")]
    return []


# --------------------------------------------------------------------------- #
# Advisory analyses (warnings — never block execution)
# --------------------------------------------------------------------------- #
def _lane_padded_width(spec: SpTTNSpec, spos: Mapping[str, int],
                       inds: Sequence[str]) -> int:
    w = 1
    for x in inds:
        if x not in spos:
            w *= spec.dims[x]
    return -(-w // _LANE) * _LANE


def vmem_diagnostics(spec: SpTTNSpec, path: ContractionPath, *,
                     block=None, itemsize: int = 4,
                     budget: int = DEFAULT_VMEM_BUDGET) -> list[Diagnostic]:
    """W003: coarse per-stage VMEM scratch estimate for the Pallas row
    lowering — one ``(block, lane-padded width)`` buffer per operand plus
    a sublane-tall output-row accumulator.  Advisory only: the compiler's
    real occupancy decides, but an estimate over budget is a strong hint
    the block axis should shrink or a dense mode should slice."""
    spos = _spos(spec)
    b = block if isinstance(block, int) and block > 0 else 128
    diags: list[Diagnostic] = []
    for i, term in enumerate(path):
        if not any(x in spos for x in term.indices):
            continue  # dense fallback stage: no Pallas scratch
        operands = itemsize * b * (
            _lane_padded_width(spec, spos, term.lhs.indices)
            + _lane_padded_width(spec, spos, term.rhs.indices))
        accum = itemsize * _SUBLANE * _lane_padded_width(
            spec, spos, term.out.indices)
        scratch = operands + accum
        if scratch > budget:
            diags.append(diag(
                "SPTTN-W003", f"term[{i}]",
                f"estimated VMEM scratch {scratch} bytes for term {i} "
                f"exceeds budget estimate {budget} bytes at block={b}",
                "shrink the block axis or slice a dense mode "
                "(memory_budget)"))
    return diags


_DTYPE_RANK = {"bool": 0, "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
               "int32": 3, "uint32": 3, "int64": 4, "uint64": 4,
               "float16": 5, "bfloat16": 5, "float32": 6, "float64": 7}


def dtype_diagnostics(spec: SpTTNSpec, path: ContractionPath,
                      dtypes: Mapping[str, str] | None) -> list[Diagnostic]:
    """W004: trace numpy-style promotion through the crossing buffers.
    A widened buffer (e.g. a float64 factor meeting float32 leaf values)
    is legal — every engine accumulates at the promoted dtype — but the
    caller should know the whole downstream chain pays for the width."""
    if not dtypes:
        return []
    env = {t.name: str(dtypes.get(t.name, "float32")) for t in spec.inputs}
    diags: list[Diagnostic] = []
    for i, term in enumerate(path):
        lt = env.get(term.lhs.name, "float32")
        rt = env.get(term.rhs.name, "float32")
        out_dt = lt if _DTYPE_RANK.get(lt, 6) >= _DTYPE_RANK.get(rt, 6) else rt
        env[term.out.name] = out_dt
        if i < len(path) - 1 and (out_dt != lt or out_dt != rt):
            diags.append(diag(
                "SPTTN-W004", f"term[{i}]",
                f"crossing buffer {term.out.name!r} promotes {lt} * {rt} "
                f"-> {out_dt}; downstream stages accumulate at the "
                "widened dtype"))
    return diags
