"""Static plan verification: loop-nest legality as a checkable property.

The paper's invariants (storage-prefix rule, strictly-descending fused
chains, zero-on-pads stackability, tile divisibility, slice-mode kind,
dtype promotion, mesh shape) re-derived symbolically into one pass —
:func:`verify_plan` — that every engine, the autotuner, the serving
tier, and CI consult *before* any kernel is built.  The engines' own
guards (``fusible_chains``, ``stackable_plan``, ``_check_block_grid``,
the slice validators) are thin wrappers over
:mod:`repro.analysis.invariants`, so routing and verification can never
disagree.
"""
from repro.analysis.diagnostics import (DIAGNOSTIC_CODES, Diagnostic,
                                        PlanReport, PlanVerificationError,
                                        diag)
from repro.analysis.invariants import (BACKENDS, chain_diagnostics,
                                       check_backend, check_block,
                                       check_block_grid, check_mesh,
                                       check_order, check_path_output,
                                       check_slice, dtype_diagnostics,
                                       fusible_chains, plan_layout_walk,
                                       stackable_diagnostics,
                                       vmem_diagnostics)
from repro.analysis.verify import verify_plan

__all__ = [
    "BACKENDS",
    "DIAGNOSTIC_CODES",
    "Diagnostic",
    "PlanReport",
    "PlanVerificationError",
    "chain_diagnostics",
    "check_backend",
    "check_block",
    "check_block_grid",
    "check_mesh",
    "check_order",
    "check_path_output",
    "check_slice",
    "diag",
    "dtype_diagnostics",
    "fusible_chains",
    "plan_layout_walk",
    "stackable_diagnostics",
    "verify_plan",
    "vmem_diagnostics",
]
