from repro.serve import serve_step
from repro.serve.serve_step import (PlanService, Request, Server, ServeStats,
                                    moe_dispatch_spec, moe_routing_coo)

__all__ = ["serve_step", "Server", "Request", "PlanService", "ServeStats",
           "moe_dispatch_spec", "moe_routing_coo"]
