from repro.serve import serve_step
from repro.serve.serve_step import Server

__all__ = ["serve_step", "Server"]
