"""Serving runtime: batched prefill + decode with slot-based continuous
batching.  A fixed pool of B slots holds independent sequences; finished
slots are refilled from the queue without stopping the decode loop (the
static-shape analogue of continuous batching — slot count and cache length
never change, so one compiled decode_step serves the whole run)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (T,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Single-host reference server; the launch driver wraps it in jit with
    mesh shardings (batch over data, heads over model)."""

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.caches = init_cache(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slot(self, s: int):
        if not self.queue:
            return
        req = self.queue.pop(0)
        T = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        logits, caches1 = prefill(self.params, self.cfg, batch,
                                  cache_len=self.cache_len)
        # splice the single-row cache into slot s of the pooled cache
        self.caches = jax.tree.map(
            lambda pool, one: _splice(pool, one, s), self.caches, caches1)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        self.active[s] = req
        self.pos[s] = T

    def step(self):
        """One decode step across all active slots."""
        for s in range(self.slots):
            if self.active[s] is None:
                self._fill_slot(s)
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                toks[s, 0] = req.out[-1]
        # all slots share one position counter per step in this reference
        # implementation: use per-slot position via max (static-shape safe)
        pos = int(self.pos.max()) if self.pos.max() > 0 else 0
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.pos[s] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[s] = None

    def run(self, max_steps: int = 64) -> list[Request]:
        finished = []
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            before = [a for a in self.active]
            self.step()
            for a in before:
                if a is not None and a.done:
                    finished.append(a)
        return finished


def _splice(pool, one, s: int):
    """Insert a batch-1 cache leaf into slot s of the pooled cache leaf
    (the batch axis is the first axis where the shapes disagree — scan
    stacks prepend a layer-group axis shared by both)."""
    if pool.shape == one.shape:
        return one.astype(pool.dtype)
    for ax in range(pool.ndim):
        if one.shape[ax] == 1 and pool.shape[ax] != 1:
            return jax.lax.dynamic_update_slice_in_dim(
                pool, one.astype(pool.dtype), s, axis=ax)
    return pool
