"""Serving runtime (DESIGN.md §9): batched prefill + decode with slot-based
continuous batching, plus the SpTTN plan-cache hot path.

:class:`Server` holds a fixed pool of B slots of independent sequences;
finished slots are refilled from the queue without stopping the decode loop
(the static-shape analogue of continuous batching — slot count and cache
length never change, so one compiled decode_step serves the whole run).

:class:`PlanService` is the serving-side owner of the autotuner stack: it
resolves every incoming sparsity pattern to a tuned plan through three
tiers — exact-key hit, bucketed-profile hit (guarded by the cost model),
cold autotune — and executes MoE dispatch through the winner.  A stream of
perturbed routing patterns pays ONE search, then runs hot.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from collections.abc import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, init_cache, prefill
from repro.sparse.coo import COOTensor, from_coords
from repro.sparse.csf import CSFTensor, build_csf, build_csf_batch


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (T,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Single-host reference server; the launch driver wraps it in jit with
    mesh shardings (batch over data, heads over model)."""

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.caches = init_cache(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self.queue: collections.deque[Request] = collections.deque()
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    def submit(self, req: Request):
        if len(req.prompt) > self.cache_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds cache_len "
                f"{self.cache_len}; raise cache_len or truncate the prompt")
        self.queue.append(req)

    def _fill_slot(self, s: int):
        if not self.queue:
            return
        req = self.queue.popleft()
        T = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        logits, caches1 = prefill(self.params, self.cfg, batch,
                                  cache_len=self.cache_len)
        # splice the single-row cache into slot s of the pooled cache
        self.caches = jax.tree.map(
            lambda pool, one: _splice(pool, one, s), self.caches, caches1)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        self.active[s] = req
        self.pos[s] = T

    def _sweep(self, finished: list[Request]):
        """Retire every slot whose request reached max_new."""
        for s, req in enumerate(self.active):
            if req is not None and len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.active[s] = None

    def step(self) -> list[Request]:
        """One decode step across all active slots; returns the requests
        that finished during this step (including ones done straight out
        of prefill — max_new=1 never reaches the decode at all)."""
        finished: list[Request] = []
        while True:
            for s in range(self.slots):
                if self.active[s] is None:
                    self._fill_slot(s)
            n = len(finished)
            self._sweep(finished)
            # a sweep that freed slots may admit more queued work before
            # the (expensive) decode launch; loop until admission settles
            if len(finished) == n or not self.queue:
                break
        if all(a is None for a in self.active):
            return finished
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out:
                toks[s, 0] = req.out[-1]
        # per-slot positions: each sequence decodes at its own depth, so
        # mixed-length prompts read/write the right cache rows
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(toks),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.pos[s] += 1
        self._sweep(finished)
        return finished

    def run(self, max_steps: int = 64) -> list[Request]:
        finished = []
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            finished.extend(self.step())
        return finished


# --------------------------------------------------------------------------- #
# SpTTN plan-cache hot path (DESIGN.md §9)
# --------------------------------------------------------------------------- #
def moe_routing_coo(idx: np.ndarray, n_experts: int,
                    capacity: int) -> COOTensor:
    """The MoE routing tensor D(t, e, c) as a sparse COO pattern.

    Numpy mirror of :func:`repro.models.moe._slot_positions`: capacity
    slots are assigned in token order per expert (dropless inference
    semantics — overflow drops trailing choices), so the pattern matches
    what the fused grouped dispatch executes.

    >>> D = moe_routing_coo(np.array([[0, 1], [1, 0], [1, 1]]), 2, 2)
    >>> D.shape, D.nnz           # third token's duplicate expert overflows
    ((3, 2, 2), 5)
    """
    idx = np.asarray(idx)
    N, k = idx.shape
    flat = idx.reshape(-1).astype(np.int64)
    order = np.argsort(flat, kind="stable")
    counts = np.bincount(flat, minlength=n_experts)
    starts = np.cumsum(counts) - counts
    rank = np.empty(flat.shape[0], np.int64)
    rank[order] = np.arange(flat.shape[0]) - starts[flat[order]]
    keep = rank < capacity
    coords = np.stack([np.repeat(np.arange(N), k)[keep],
                       flat[keep], rank[keep]], axis=1).astype(np.int32)
    values = np.ones(int(keep.sum()), np.float32)
    return from_coords(coords, values, (N, n_experts, capacity),
                       sum_duplicates=False)


def moe_dispatch_spec(n_tokens: int, n_experts: int, capacity: int,
                      d_model: int):
    """SpTTN spec of MoE dispatch  Xe(e,c,d) = sum_t D(t,e,c) * X(t,d)."""
    from repro.core.spec import parse
    return parse("tec,td->ecd",
                 dims={"t": n_tokens, "e": n_experts, "c": capacity,
                       "d": d_model}, sparse=0, names=["D", "X"])


@dataclasses.dataclass
class ServeStats:
    """How one request's plan was resolved (assertable by tests/benches)."""

    kind: str            # "cold" (fresh search) | "exact" | "bucket"
    key: str             # exact cache key of the request's true profile
    bucket_key: str      # bucketed key consulted ("" = bucketing off)
    seconds: float       # plan-resolution wall-clock (search or lookup)


class PlanService:
    """Serving-side owner of the plan cache, bucketer, and executors.

    Request flow per pattern (DESIGN.md §9):

    1. exact key in the in-process plan map  -> "exact" (no disk, no model)
    2. bucketed key in the in-process map, and the cost-model guard admits
       the plan on the request's true profile -> "bucket"
    3. :func:`repro.autotune.tuner.tune` with ``cache_dir`` — which itself
       checks the exact and bucketed *disk* entries before searching ->
       "exact"/"bucket" (disk hit) or "cold" (fresh search, persisted
       under both keys for every later request in the bucket)

    Execution is eager (no jit): perturbed patterns change array sizes
    every request, so a compiled path would retrace per pattern — the
    opposite of a hot path.

    ``memory_budget`` (bytes) applies the out-of-core regime of
    DESIGN.md §10 per request: every resolved plan is stamped with the
    slice decision for the request's true nnz profile, and over-budget
    dispatches replay the one tuned schedule chunk by chunk (chunk
    executors are cached like whole-plan executors).  ``tuner`` is the
    blessed spelling of the TunerConfig kwarg; ``config`` stays accepted.
    """

    def __init__(self, cache_dir: str | None = None, config=None, *,
                 tuner=None, memory_budget: int | None = None):
        from repro.autotune.tuner import TunerConfig
        if tuner is not None and config is not None:
            raise ValueError("PlanService() got both tuner= and config= "
                             "(aliases for the same TunerConfig)")
        self.cache_dir = cache_dir
        self.config = tuner or config or TunerConfig(
            profile_bucket="log2", max_paths=4, max_candidates=4,
            orders_per_path=1, warmup=0, repeats=1)
        self.memory_budget = memory_budget
        self.stats: list[ServeStats] = []
        self._plans: dict = {}          # exact key -> plan
        self._bucket_plans: dict = {}   # bucketed key -> plan
        self._executors: dict = {}      # plan json -> engine instance
        self._chunk_executors: dict = {}   # plan json -> {width: engine}

    def plan_for(self, spec, csf: CSFTensor):
        """Resolve (spec, pattern) to a tuned plan; returns (plan, stats)."""
        from repro.autotune import tuner as T
        from repro.autotune.cache import (bucketed_cache_key, cache_key,
                                          device_kind)
        t0 = time.perf_counter()
        levels = csf.nnz_levels()
        device = device_kind()
        backends = self.config.backends or T.default_backends()
        key = cache_key(spec, levels, device, backends=backends,
                        mesh=self.config.mesh, blocks=self.config.blocks)
        bkey = ""
        if self.config.profile_bucket is not None:
            bkey = bucketed_cache_key(
                spec, levels, device, backends=backends,
                mesh=self.config.mesh, blocks=self.config.blocks,
                scheme=self.config.profile_bucket)
        if key in self._plans:
            plan, kind = self._plans[key], "exact"
        elif bkey and bkey in self._bucket_plans and T._bucket_reuse_ok(
                self._bucket_plans[bkey], spec, levels, self.config,
                T.SearchStats()):
            plan, kind = self._bucket_plans[bkey], "bucket"
            if self.memory_budget is not None:
                # a bucket-mate's profile, not this one: re-price slicing
                from repro.core.slicing import stamp_plan_slicing
                plan = stamp_plan_slicing(plan, levels, self.memory_budget)
            self._plans[key] = plan   # promote: next time it's an exact hit
        else:
            plan, tstats = T.tune(spec, csf=csf, cache_dir=self.cache_dir,
                                  tuner=self.config,
                                  memory_budget=self.memory_budget)
            kind = ("bucket" if tstats.bucket_hit
                    else "exact" if tstats.cache_hit else "cold")
            # static pre-flight before the plan enters the serving tiers:
            # a corrupt disk-cache entry is rejected once, here, with a
            # structured diagnostic — the in-memory exact/bucket tiers
            # above only ever hold plans that passed (DESIGN.md §11)
            from repro.analysis import verify_plan
            verify_plan(plan).raise_if_error("PlanService.plan_for")
            self._plans[key] = plan
            if bkey:
                self._bucket_plans[bkey] = plan
        st = ServeStats(kind=kind, key=key, bucket_key=bkey,
                        seconds=time.perf_counter() - t0)
        self.stats.append(st)
        return plan, st

    def _executor_for(self, plan):
        from repro.core.executor import make_executor, plan_to_json
        pkey = plan_to_json(plan)
        ex = self._executors.get(pkey)
        if ex is None:
            kwargs = {}
            from repro.analysis.diagnostics import PALLAS_BACKENDS
            if plan.backend in PALLAS_BACKENDS:
                if plan.fused:
                    kwargs["strategy"] = "fused"
                if plan.block:
                    kwargs["block"] = plan.block
            ex = make_executor(plan.spec, plan.path, plan.order,
                               backend=plan.backend, **kwargs)
            self._executors[pkey] = ex
        return ex

    def dispatch(self, routing: "COOTensor | CSFTensor", x):
        """MoE dispatch Xe(e,c,d) = sum_t D(t,e,c) X(t,d) through a tuned
        plan; returns (Xe as a jnp array, ServeStats)."""
        from repro.core.executor import CSFArrays
        csf = routing if isinstance(routing, CSFTensor) else \
            build_csf(routing)
        N, E, C = csf.shape
        spec = moe_dispatch_spec(N, E, C, int(np.shape(x)[-1]))
        plan, st = self.plan_for(spec, csf)
        factors = {"X": jnp.asarray(x)}
        if getattr(plan, "slice_chunks", 1) > 1:
            # over-budget request: replay the one tuned schedule chunk by
            # chunk, reusing compiled chunk executors across requests
            from repro.core.executor import plan_to_json
            from repro.core.slicing import sliced_execute
            cache = self._chunk_executors.setdefault(plan_to_json(plan), {})
            out = sliced_execute(plan, CSFArrays.from_csf(csf), factors,
                                 executor_cache=cache)
            return out, st
        ex = self._executor_for(plan)
        out = ex(CSFArrays.from_csf(csf), factors)
        return out, st

    def dispatch_batch(self, routings: Sequence[COOTensor], xs):
        """Batched request path: one amortized CSF construction pass
        (:func:`repro.sparse.csf.build_csf_batch`), then per-request plan
        resolution + dispatch.  Returns a list of (output, stats)."""
        csfs = build_csf_batch(list(routings))
        return [self.dispatch(csf, x) for csf, x in zip(csfs, xs)]


def _splice(pool, one, s: int):
    """Insert a batch-1 cache leaf into slot s of the pooled cache leaf
    (the batch axis is the first axis where the shapes disagree — scan
    stacks prepend a layer-group axis shared by both)."""
    if pool.shape == one.shape:
        return one.astype(pool.dtype)
    for ax in range(pool.ndim):
        if one.shape[ax] == 1 and pool.shape[ax] != 1:
            return jax.lax.dynamic_update_slice_in_dim(
                pool, one.astype(pool.dtype), s, axis=ax)
    return pool
