"""Top-level facade: the blessed public surface of the reproduction.

Everything a user workflow needs — spec building, planning, tuning,
execution (budgeted or not), CSF construction, caching, serving — is
importable from ``repro`` directly::

    from repro import mttkrp, build_csf, random_sparse, plan, execute_plan

The deep module paths (``repro.core.planner`` etc.) keep working and are
where the implementation docs live; the facade is the stable spelling.

Exports resolve lazily (PEP 562): ``import repro`` touches no submodule,
so it never triggers a JAX import/compile — the first *attribute* access
pays the import of exactly the module that defines it.
"""
from __future__ import annotations

import importlib

__version__ = "0.8.0"

# name -> defining module (the single source of truth for __all__)
_EXPORTS = {
    # kernel specs (repro.core.spec)
    "SpTTNSpec": "repro.core.spec",
    "parse": "repro.core.spec",
    "mttkrp": "repro.core.spec",
    "ttmc3": "repro.core.spec",
    "ttmc4": "repro.core.spec",
    "tttp3": "repro.core.spec",
    "sddmm": "repro.core.spec",
    "tttc6": "repro.core.spec",
    # sparse construction (repro.sparse)
    "COOTensor": "repro.sparse",
    "CSFTensor": "repro.sparse",
    "random_sparse": "repro.sparse",
    "from_dense": "repro.sparse",
    "build_csf": "repro.sparse",
    "build_csf_batch": "repro.sparse",
    # planning (repro.core.planner)
    "plan": "repro.core.planner",
    "cached_plan": "repro.core.planner",
    "SpTTNPlan": "repro.core.planner",
    # execution (repro.core.executor)
    "make_executor": "repro.core.executor",
    "execute_plan": "repro.core.executor",
    "CSFArrays": "repro.core.executor",
    "dense_oracle": "repro.core.executor",
    "plan_to_json": "repro.core.executor",
    "plan_from_json": "repro.core.executor",
    "BACKENDS": "repro.core.executor",
    # memory-budgeted slicing (repro.core.slicing, DESIGN.md §10)
    "plan_peak_bytes": "repro.core.slicing",
    "choose_slicing": "repro.core.slicing",
    "sliced_execute": "repro.core.slicing",
    "SliceDecision": "repro.core.slicing",
    "MemoryBudgetError": "repro.core.slicing",
    # autotuning + persistent plan cache (repro.autotune)
    "tune": "repro.autotune.tuner",
    "TunerConfig": "repro.autotune.tuner",
    "SearchStats": "repro.autotune.tuner",
    "PlanCache": "repro.autotune.cache",
    # static plan verification (repro.analysis, DESIGN.md §11)
    "verify_plan": "repro.analysis",
    "Diagnostic": "repro.analysis",
    "PlanReport": "repro.analysis",
    "PlanVerificationError": "repro.analysis",
    # serving (repro.serve)
    "PlanService": "repro.serve.serve_step",
}

__all__ = sorted(_EXPORTS) + ["__version__"]


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value          # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
