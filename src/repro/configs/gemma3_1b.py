"""gemma3-1b [dense]: 26L d_model=1152 4H (MQA kv=1, head_dim=256)
d_ff=6912 vocab=262144.  5 local : 1 global pattern, 512-token window,
qk-norm, gemma post-norms. [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=512,
    norm="rmsnorm",
    post_norms=True,
    qk_norm=True,
    mlp="geglu",
    tie_embeddings=True,
    emb_scale=True,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab=256, window=16, dtype="float32")
