"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-32B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    block_pattern=("attn",),
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256, dtype="float32")
