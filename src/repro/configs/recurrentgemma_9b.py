"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 attn:recurrent
pattern.  38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    norm="rmsnorm",
    mlp="geglu",
    tie_embeddings=True,
    emb_scale=True,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab=256, window=16, dtype="float32")
