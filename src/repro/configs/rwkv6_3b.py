"""rwkv6-3b [ssm]: Finch, 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536; data-dependent decay WKV6 recurrence. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # 2560 / 64 WKV heads
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    block_pattern=("rwkv",),
    norm="layernorm",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        d_ff=256, vocab=256, dtype="float32")
