"""Model/run configuration dataclasses for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0
    capacity_factor: float = 1.25
    # 'auto' consults the SpTTN planner; 'grouped' = factorize-and-fuse
    # (sort + grouped GEMM); 'onehot' = unfactorized dense einsum baseline
    dispatch: Literal["auto", "grouped", "onehot"] = "auto"
    first_dense: int = 0          # leading layers with a dense FFN instead
    d_first_dense: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims."""
    kv_lora: int = 512
    q_lora: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None   # explicit (gemma3); default d_model//heads
    # block pattern repeated over layers, e.g. ("rglru","rglru","attn")
    block_pattern: tuple[str, ...] = ("attn",)
    window: int | None = None     # sliding-window size for 'local' blocks
    qkv_bias: bool = False
    norm: Literal["rmsnorm", "layernorm", "nonparam_ln"] = "rmsnorm"
    post_norms: bool = False      # gemma-style post-attn/ffn norms
    qk_norm: bool = False
    mlp: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mla_absorb: bool = True       # absorbed-matrix MLA decode (§Perf)
    rwkv: bool = False
    encdec: bool = False
    n_enc_layers: int = 0
    modality_stub: Literal["none", "vision", "audio"] = "none"
    n_stub_tokens: int = 256      # patch/frame embeddings from the stub
    dtype: str = "bfloat16"
    pad_vocab_to: int = 128       # pad embedding rows for TP divisibility
    logit_softcap: float = 0.0
    emb_scale: bool = False       # gemma-style sqrt(d_model) embed scaling

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = max(self.pad_vocab_to, 1)
        return ((self.vocab + m - 1) // m) * m

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid/linear-attn or mostly-windowed."""
        kinds = set(self.block_pattern)
        return bool(kinds & {"rglru", "rwkv", "local"})

    def pattern_for_layers(self, n: int | None = None) -> list[str]:
        n = n or self.n_layers
        p = []
        while len(p) < n:
            p.extend(self.block_pattern)
        return p[:n]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving hyperparameters used by launch drivers."""
    model: ModelConfig
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    grad_clip: float = 1.0
    microbatches: int = 1         # grad-accumulation steps
    remat: bool = True
    scan_unroll: bool = False     # dry-run cost probes unroll layer scans
    kv_cache_dtype: str = "bfloat16"
    seed: int = 0
