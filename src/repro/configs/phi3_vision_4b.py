"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP stub.  32L
d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.  The vision frontend is a
STUB: input_specs() provides precomputed patch embeddings occupying the
first n_stub_tokens positions. [hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    block_pattern=("attn",),
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    modality_stub="vision",
    n_stub_tokens=256,
    rope_theta=500000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, n_stub_tokens=4, dtype="float32")
