"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA kv_lora=512,
d_ff(expert)=1536 vocab=102400, 160 routed experts top-6 + 2 shared,
first layer dense (d_ff=12288). [arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab=102400,
    block_pattern=("attn",),
    norm="rmsnorm",
    mlp="swiglu",
    tie_embeddings=False,
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536,
                  n_shared=2, d_shared=1536,
                  first_dense=1, d_first_dense=12288),
    mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=256, dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                      d_shared=32, first_dense=1, d_first_dense=128),
        mla=MLAConfig(kv_lora=32, q_lora=48, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16))
