"""Architecture registry + input_specs for the dry-run.

``get_config(arch)`` / ``get_reduced(arch)`` return full/smoke ModelConfigs;
``input_specs(cfg, shape, ...)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable, no
device allocation.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, RunConfig, ShapeConfig

_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "olmo-1b": "olmo_1b",
    "gemma3-1b": "gemma3_1b",
    "qwen1.5-32b": "qwen1_5_32b",
    "smollm-135m": "smollm_135m",
    "rwkv6-3b": "rwkv6_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "seamless-m4t-large-v2": "seamless_m4t_large",
    "phi-3-vision-4.2b": "phi3_vision_4b",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _mod(arch).reduced()


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic families
    (DESIGN.md §5); every arch here is generative so decode always runs."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped per spec"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str | ShapeConfig,
                for_loss: bool = True) -> dict:
    """ShapeDtypeStruct stand-ins for the cell's step function inputs."""
    sc = SHAPES[shape] if isinstance(shape, str) else shape
    B, S = sc.global_batch, sc.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if sc.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    else:
        batch = {"tokens": tok}
        if for_loss and sc.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.modality_stub == "vision" and sc.kind != "decode":
        batch["stub"] = jax.ShapeDtypeStruct(
            (B, cfg.n_stub_tokens, cfg.d_model), cfg.compute_dtype)
    if cfg.encdec and sc.kind != "decode":
        # audio stub: precomputed frame embeddings for the encoder
        batch["enc_frames"] = jax.ShapeDtypeStruct(
            (B, S // 4, cfg.d_model), cfg.compute_dtype)
    return batch


def make_batch(cfg: ModelConfig, shape: str | ShapeConfig, seed: int = 0,
               batch_override: int | None = None,
               seq_override: int | None = None) -> dict:
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    import numpy as np
    sc = SHAPES[shape] if isinstance(shape, str) else shape
    B = batch_override or sc.global_batch
    S = seq_override or sc.seq_len
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if sc.kind == "train":
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.modality_stub == "vision":
        n = min(cfg.n_stub_tokens, S)
        batch["stub"] = jnp.asarray(
            rng.standard_normal((B, n, cfg.d_model)), cfg.compute_dtype)
    if cfg.encdec:
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, max(1, S // 4), cfg.d_model)),
            cfg.compute_dtype)
    return batch


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "RunConfig", "ShapeConfig",
           "get_config", "get_reduced", "shape_applicable", "input_specs",
           "make_batch"]
