"""seamless-m4t-large-v2 [audio]: enc-dec transformer backbone, 24L
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  The speech frontend is a
STUB per the assignment: input_specs() provides precomputed frame
embeddings for the encoder. [arXiv:2308.11596]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    block_pattern=("attn",),
    norm="layernorm",
    mlp="gelu",
    tie_embeddings=True,
    encdec=True,
    n_enc_layers=24,
    modality_stub="audio",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, dtype="float32")
