"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no learned affine), untied head per OLMo.
[arXiv:2402.00838]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    block_pattern=("attn",),
    norm="nonparam_ln",
    mlp="swiglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, dtype="float32")
