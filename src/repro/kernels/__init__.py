# Pallas TPU kernels for the perf-critical compute layers, each with a
# pure-jnp oracle in ref.py and a jit'd wrapper in ops.py:
#   codegen/                  — generated fused kernels for ANY SpTTN plan
#                               (the backend="pallas" engine, DESIGN.md §6)
#   mttkrp / ttmc / tttp      — hand-written SpTTN hot loops (Eqs. 1-3);
#                               regression fixtures for the generator
#   grouped_matmul            — MoE expert GEMM (SpTTN-planned dispatch)
#   wkv6 / rglru / local_attn — recurrence & block-sparse attention kernels
# All validated in interpret mode on CPU; BlockSpecs are sized for v5e VMEM.
from repro.kernels import codegen, ops, ref

__all__ = ["codegen", "ops", "ref"]
