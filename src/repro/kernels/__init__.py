# Pallas TPU kernels for the perf-critical compute layers, each with a
# pure-jnp oracle in ref.py and a jit'd wrapper in ops.py:
#   mttkrp / ttmc / tttp      — the paper's SpTTN hot loops (Eqs. 1-3)
#   grouped_matmul            — MoE expert GEMM (SpTTN-planned dispatch)
#   wkv6 / rglru / local_attn — recurrence & block-sparse attention kernels
# All validated in interpret mode on CPU; BlockSpecs are sized for v5e VMEM.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
