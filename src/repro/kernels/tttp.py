"""Pallas TPU kernel: TTTP / generalized SDDMM leaf (paper Eq. 3).

out[n] = vals[n] * sum_r U[i_n,r] V[j_n,r] W[k_n,r]

Embarrassingly parallel over nonzero blocks; the kernel fuses the 3-way
Hadamard and the R-reduction in VMEM (one pass over the gathered rows, no
(nnz, R) HBM temporaries).  The same kernel with W=1 is exactly SDDMM —
the static-pattern sparse-attention logit kernel (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _kernel(vals_ref, ug_ref, vg_ref, wg_ref, o_ref):
    prod = ug_ref[...] * vg_ref[...] * wg_ref[...]
    o_ref[...] = vals_ref[...] * jnp.sum(prod, axis=1, keepdims=True)


def tttp_pallas(vals: jnp.ndarray, ug: jnp.ndarray, vg: jnp.ndarray,
                wg: jnp.ndarray, block: int = DEFAULT_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """vals (P, 1); ug/vg/wg (P, R) gathered factor rows (P padded to block).

    VMEM per step: ~3*block*R*4B; block=512, R=64 -> 384 KiB.
    """
    P, R = ug.shape
    assert P % block == 0
    grid = (P // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, R), lambda i: (i, 0)),
            pl.BlockSpec((block, R), lambda i: (i, 0)),
            pl.BlockSpec((block, R), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 1), vals.dtype),
        interpret=interpret,
    )(vals, ug, vg, wg)
