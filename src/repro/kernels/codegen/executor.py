"""PallasPlanExecutor — lower any fused SpTTN plan to Pallas kernels.

Structural sibling of :class:`~repro.core.executor.VectorizedExecutor`
(it *is* one, by inheritance): operand lifting, dense fallbacks, and
final-output materialization are shared, so the two engines agree by
construction everywhere except the lowering unit — ``_fiber_contract``,
where the XLA engine's einsum + ``segment_sum`` is replaced by generated
Pallas stages.  The executor emits *target-neutral* stage IR
(kernels/codegen/ir.py) and hands it to the registered
:class:`~repro.kernels.codegen.ir.Lowering` for its ``target``:
``"tpu"`` (stages.py, sequential-grid VMEM accumulator — the
``backend="pallas"`` engine) or ``"gpu"`` (lower_gpu.py, split-K +
segment combine — the ``backend="pallas-gpu"`` engine).  The emitted IR
is byte-identical across targets; only the lowering differs.

Per reducing term the generator picks one of two lowerings from the
static segment profile (pattern-known, so the choice is trace-time):

* **row** — the mttkrp-style fused kernel: fibers padded per output
  segment to block multiples (``padded_segment_layout`` at arbitrary
  (lvl, out_lvl), not just leaf->root), output row accumulated in VMEM
  with the Algorithm-2 reset.  Chosen when segments are block-sized —
  padding stays bounded.
* **segsum** — a fused product stage (hadamard/dot in VMEM) followed by
  an XLA segmented sum.  Chosen when segments are tiny (e.g. leaf ->
  next level), where block-per-segment padding would explode.

Gathers stay in XLA on purpose: TPU-native big fast gathers feed the
kernels, matching the hand-written MTTKRP kernel this module retires as
a special case (it survives as the generator's regression fixture).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

# Chain legality is a static invariant owned by the verifier; this
# module is where the chains are *lowered*, so it re-exports the
# detector — the tuner and the distributed engine import it from either
# place and get the same single implementation.
from repro.analysis.invariants import fusible_chains  # noqa: F401
from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 default_interpret)
from repro.core.loopnest import LoopOrder
from repro.core.paths import ContractionPath
from repro.core.spec import SpTTNSpec
# importing the lowering modules registers the built-in targets
from repro.kernels.codegen import lower_gpu, stages  # noqa: F401
from repro.kernels.codegen.ir import (TILE_SUBLANE, ChainLink, Stage,
                                      StageIR, StageOperand, get_lowering)
from repro.kernels.util import padded_segment_layout, round_up

DEFAULT_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class SegmentProfile:
    """Static reduction profile of one (lvl → out_lvl) CSF segment map.

    This is everything the strategy choice reads about the pattern, and it
    is computed from the *operand actually being executed* — in the
    distributed engine that is a shard's local CSF, so each shard picks
    its lowering from its own nonzero distribution (a skewed shard may
    take ``row`` while a sparse one takes ``segsum``; DESIGN.md §7).
    """

    lvl: int
    out_lvl: int
    nfib: int            # level-``lvl`` fibers entering the reduction
    nseg: int            # level-``out_lvl`` output rows
    max_seg: int         # longest segment (fibers feeding one output row)
    mean_seg: float      # nfib / nseg

    @staticmethod
    def row_decision(nfib: int, nseg: int, block: int) -> bool:
        """The strategy formula on the O(1) fiber counts alone: row wins
        when block-per-segment padding stays within ~4x of the fiber
        count (small kernels always qualify via the absolute floor)."""
        return nseg * block <= max(4 * nfib, 4 * block)

    def prefers_row(self, block: int) -> bool:
        """True when the fused VMEM row accumulator is the better
        lowering for this profile; otherwise fall back to ``segsum``."""
        return self.row_decision(self.nfib, self.nseg, block)


# --------------------------------------------------------------------- #
# Static layout cache (pattern-fixed, stored on the CSFArrays instance)
#
# Entry formats — owned here so every producer agrees with the consumers
# in ``_fiber_contract`` / ``_exec_chain``:
#   stage key (lvl, out_lvl, block) ->
#       (lay, gather, mask[:, None], block_seg, block_first)
#   chain key ("chain", lvl0, levels, block) ->
#       (lay, gather, mask[:, None], segs, firsts, lasts[:-1])
# ``lay`` is consulted only for its static ``nseg`` at trace time; the
# array slots may be numpy constants (single-device path) OR traced values
# (the stacked distributed engine pre-populates the cache inside
# shard_map with per-shard slices of mesh-stacked layouts, which is what
# lets ONE kernel trace serve every shard).
# --------------------------------------------------------------------- #
def layout_cache(csf: CSFArrays) -> dict:
    """The per-operand static layout cache (created on first use)."""
    return csf.__dict__.setdefault("_codegen_layouts", {})


def stage_layout_key(lvl: int, out_lvl: int, block: int) -> tuple:
    return (lvl, out_lvl, block)


def chain_layout_key(lvl0: int, levels: tuple, block: int) -> tuple:
    return ("chain", lvl0, tuple(levels), block)


def stage_cache_entry(lay, gather, mask, block_seg, block_first) -> tuple:
    """Assemble a row-stage cache entry; ``mask`` is the flat (P,) mask
    (the trailing unit lane is added here)."""
    return (lay, gather, mask[:, None], block_seg, block_first)


def chain_cache_entry(lay, gather, mask, segs, firsts, lasts) -> tuple:
    """Assemble a fused-chain cache entry; ``lasts`` excludes the
    outermost level (the final flush is the grid's end)."""
    return (lay, gather, mask[:, None], tuple(segs), tuple(firsts),
            tuple(lasts))


def chain_block_arrays(csf, lvl0: int, levels: tuple, block: int):
    """Numpy block-level chain layout: padded innermost layout plus the
    per-block segment ids / first flags / last flags at every chain
    level (``lasts`` covers all levels; ``_chain_layout`` drops the
    outermost).  ``csf`` needs only ``.seg`` and ``.nfib``, so the
    stacked distributed engine can feed padded per-shard numpy arrays
    through the same math it would trace with.
    """
    seg0 = np.asarray(csf.seg[(lvl0, levels[0])])
    lay = padded_segment_layout(seg0, csf.nfib[levels[0]], block)

    def firsts_of(seg: np.ndarray) -> np.ndarray:
        f = np.zeros(len(seg), np.int32)
        f[0] = 1
        f[1:] = seg[1:] != seg[:-1]
        return f

    def lasts_of(seg: np.ndarray) -> np.ndarray:
        l = np.zeros(len(seg), np.int32)
        l[-1] = 1
        l[:-1] = seg[1:] != seg[:-1]
        return l

    segs = [lay.block_seg.astype(np.int32)]
    for prev, lvl in zip(levels, levels[1:]):
        up = (np.asarray(csf.seg[(prev, lvl)])[segs[-1]] if lvl > 0
              else np.zeros_like(segs[-1]))
        segs.append(up.astype(np.int32))
    firsts = [lay.block_first.astype(np.int32)] + \
        [firsts_of(s) for s in segs[1:]]
    lasts = [lasts_of(s) for s in segs]
    return lay, segs, firsts, lasts


def segment_profile(csf: CSFArrays, lvl: int, out_lvl: int) -> SegmentProfile:
    """Profile the ``(lvl, out_lvl)`` segment map of ``csf`` (pattern-
    static; concrete per operand, hence per shard).  ``max_seg`` and
    ``mean_seg`` cost one O(nfib) pass — inspection/reporting callers
    only; the trace-time strategy choice reads just the O(1) counts."""
    nfib = csf.nfib[lvl]
    nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
    if nfib == 0:
        return SegmentProfile(lvl, out_lvl, 0, nseg, 0, 0.0)
    seg = np.asarray(csf.seg[(lvl, out_lvl)]) if out_lvl > 0 else \
        np.zeros(nfib, np.int64)
    counts = np.bincount(seg, minlength=max(nseg, 1))
    return SegmentProfile(lvl, out_lvl, nfib, nseg, int(counts.max()),
                          nfib / max(nseg, 1))


class PallasPlanExecutor(VectorizedExecutor):
    """Execute a (path, order) plan through generated Pallas kernels.

    ``strategy`` forces the reduction lowering (``"row"``/``"segsum"``)
    for tests; ``"auto"`` picks per stage from the segment profile.
    ``interpret=None`` resolves to True off-TPU (CPU validation mode).

    ``tile_align`` turns on the pad-to-tile lowering pass (DESIGN.md §8):
    every stage's lane widths are padded to ``TILE_LANE`` (128) and
    ``block`` is rounded up to a ``TILE_SUBLANE`` (8) multiple, which is
    what makes the generated kernels legal under ``interpret=False`` on
    real TPUs.  ``None`` resolves to compiled mode (``not interpret``) —
    interpret-mode validation stays unpadded by default, but the pass is
    value-preserving, so ``tile_align=True, interpret=True`` is the
    CPU-testable witness for the compiled lowering.

    ``target`` names the registered stage lowering (docs/backends.md):
    ``"tpu"`` — sequential-grid VMEM accumulation (``backend="pallas"``)
    or ``"gpu"`` — split-K + segment combine (``backend="pallas-gpu"``).
    The executor emits the same IR either way; strategy choice, layouts,
    and operand lifting are all target-independent.
    """

    def __init__(self, spec: SpTTNSpec, path: ContractionPath,
                 order: LoopOrder, block: int = DEFAULT_BLOCK,
                 interpret: bool | None = None, strategy: str = "auto",
                 tile_align: bool | None = None, target: str = "tpu"):
        super().__init__(spec, path, order)
        if strategy not in ("auto", "row", "segsum", "fused"):
            raise ValueError(f"unknown strategy {strategy!r}")
        if block < 1:
            raise ValueError(f"block must be positive, got {block}")
        self.target = target
        self.lowering = get_lowering(target)   # ValueError on unknown
        self.interpret = default_interpret() if interpret is None \
            else interpret
        self.tile_align = (not self.interpret) if tile_align is None \
            else bool(tile_align)
        self.block = round_up(block, TILE_SUBLANE) if self.tile_align \
            else block
        self.strategy = strategy
        # every Stage emitted at trace time, in emission order — the
        # shape-inspection surface for the tile-alignment tests (a fused
        # chain records (stage, links) in emitted_chains as well).  Reset
        # per trace in __call__ so a long-lived executor reflects only
        # its latest trace instead of accumulating every one.
        self.emitted_stages: list[Stage] = []
        self.emitted_chains: list[tuple[Stage, tuple[ChainLink, ...]]] = []
        # the full target-neutral IR, one entry per lowering-unit call —
        # identical across targets for the same plan/operand/settings
        # (the cross-backend tests assert it), which is what makes a
        # TPU-vs-GPU value disagreement attributable to a lowering
        self.emitted_ir: list[StageIR] = []
        # (lvl, out_lvl) -> "row" | "segsum" | "fused", recorded at trace
        # time for inspection (tests, distributed per-shard strategy
        # reporting).  A fused chain records ONE entry keyed by its
        # (innermost lvl, final out_lvl) — one entry == one kernel launch
        # for the whole chain.
        self.stage_strategy: dict[tuple[int, int], str] = {}
        # start tid -> member tids of each provably safe reducing chain;
        # executed as one kernel only under strategy="fused"
        self._chains = (fusible_chains(spec, path)
                        if strategy == "fused" else {})

    def __call__(self, csf, factors):
        self.emitted_stages.clear()
        self.emitted_chains.clear()
        self.emitted_ir.clear()
        self.stage_strategy.clear()
        return super().__call__(csf, factors)

    # -- static layouts (pattern-fixed, cached on the CSFArrays) -------- #
    def _layout(self, csf: CSFArrays, lvl: int, out_lvl: int):
        cache = layout_cache(csf)
        key = stage_layout_key(lvl, out_lvl, self.block)
        if key not in cache:
            seg = np.asarray(csf.seg[(lvl, out_lvl)])
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            lay = padded_segment_layout(seg, nseg, self.block)
            # entries stay numpy: an entry first created INSIDE one jit
            # trace must be reusable by a later trace over the same
            # operand (tuner timing several pallas-family candidates), so
            # nothing trace-bound may be cached here — each trace lifts
            # the constants itself at the use sites
            cache[key] = stage_cache_entry(
                lay, lay.gather, lay.mask,
                lay.block_seg, lay.block_first)
        return cache[key]

    def strategy_for(self, csf: CSFArrays, lvl: int, out_lvl: int) -> str:
        """Reduction lowering for this operand's (lvl, out_lvl) stage,
        chosen from its segment profile (per-shard in the distributed
        engine) unless forced by ``strategy``.  Reads only the O(1)
        fiber counts — :func:`segment_profile` exists for callers that
        want the full distribution.  Under ``strategy="fused"`` only
        chain members fuse; stages outside a chain fall back to the
        profile-driven choice here."""
        if self.strategy not in ("auto", "fused"):
            return self.strategy
        nfib = csf.nfib[lvl]
        nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
        row = SegmentProfile.row_decision(nfib, nseg, self.block)
        return "row" if row else "segsum"

    def _use_row(self, csf: CSFArrays, lvl: int, out_lvl: int) -> bool:
        choice = self.strategy_for(csf, lvl, out_lvl)
        self.stage_strategy[(lvl, out_lvl)] = choice
        return choice == "row"

    # -- fused reducing chains (DESIGN.md §6) --------------------------- #
    def _chain_len(self, tid: int) -> int:
        chain = self._chains.get(tid)
        return len(chain) if chain else 1

    def _chain_layout(self, csf: CSFArrays, lvl0: int, levels: tuple):
        """Per-block segment ids / first flags / last flags at every chain
        level, plus the padded innermost layout (pattern-static, cached on
        the CSFArrays like the single-stage layouts).

        ``levels`` are the chain's output levels innermost-first (e.g.
        MTTKRP's ``(2, 1)``); nesting of the CSF segment maps makes each
        outer array a composition of the inner one.
        """
        cache = layout_cache(csf)
        key = chain_layout_key(lvl0, levels, self.block)
        if key in cache:
            return cache[key]
        lay, segs, firsts, lasts = chain_block_arrays(csf, lvl0, levels,
                                                      self.block)
        # numpy, not jnp: see _layout — cache entries outlive any single
        # jit trace, so they must never hold trace-bound values
        entry = chain_cache_entry(
            lay, lay.gather, lay.mask,
            tuple(segs), tuple(firsts), tuple(lasts[:-1]))
        cache[key] = entry
        return entry

    def _exec_chain(self, csf: CSFArrays, factors, env: dict, tid: int,
                    length: int):
        """Lower a whole detected reducing chain to ONE Pallas kernel
        (run_fused_chain_stage): the innermost term's block contraction
        feeds a VMEM scratch crossing buffer per intermediate level, and
        segment-close flushes carry partials outward — no HBM round trip
        between the chain's stages."""
        from repro.core.executor import DenseVal, FiberVal

        tids = self._chains[tid]
        terms = [self.path[k] for k in tids]
        first = terms[0]
        lvl0 = self._sparse_level(first.indices)
        levels = tuple(self._sparse_level(t.out.indices) for t in terms)
        dims = self.spec.dims
        sp = set(self.spos)

        if csf.nfib.get(lvl0, 0) == 0:
            # degenerate pattern: fall back to the staged per-term path
            val = None
            for k in tids:
                val = self._exec_term(csf, factors, env, self.path[k])
                if k != tids[-1]:
                    env[self.path[k].out.name] = val
            return val

        a = self._get_operand(csf, factors, env, first.lhs)
        b = self._get_operand(csf, factors, env, first.rhs)
        fa, da = self._lift(csf, a, first.lhs, lvl0)
        fb, db = self._lift(csf, b, first.rhs, lvl0)
        dtype = jnp.result_type(fa.dtype, fb.dtype)

        operands, arrays = [], []
        for arr, inds in ((fa, da), (fb, db)):
            shape = tuple(dims[i] for i in inds)
            operands.append(StageOperand(
                subs="".join(self._letter[i] for i in inds),
                shape=shape, fiber=arr.ndim == len(inds) + 1))
            arrays.append(arr)
        out_dense0 = tuple(i for i in first.out.indices if i not in sp)
        out_subs = "".join(self._letter[i] for i in out_dense0)
        out_shape = tuple(dims[i] for i in out_dense0)

        lay, gather, mask, segs, firsts, lasts = \
            self._chain_layout(csf, lvl0, levels)
        nfib0 = csf.nfib[lvl0]
        padded = [
            arr.reshape(nfib0, -1)[gather] if op.fiber
            else arr.reshape(1, -1)
            for arr, op in zip(arrays, operands)]
        stage = Stage(operands=tuple(operands), out_subs=out_subs,
                      out_shape=out_shape, reduce=True, block=self.block,
                      nseg=lay.nseg, interpret=self.interpret,
                      tile=self.tile_align)

        links, link_arrays = [], []
        for pos, term in enumerate(terms[1:]):
            lvl_k = levels[pos]          # level the intermediate lives on
            inter = terms[pos].out.name
            other = term.rhs if term.lhs.name == inter else term.lhs
            val = self._get_operand(csf, factors, env, other)
            arr, dense_inds = self._lift(csf, val, other, lvl_k)
            link_ops = [StageOperand(subs=out_subs, shape=out_shape,
                                     fiber=True)]
            fiber = arr.ndim == len(dense_inds) + 1
            link_ops.append(StageOperand(
                subs="".join(self._letter[i] for i in dense_inds),
                shape=tuple(dims[i] for i in dense_inds), fiber=fiber))
            link_arrays.append(
                arr.reshape(csf.nfib[lvl_k], -1) if fiber
                else arr.reshape(1, -1))
            out_dense = tuple(i for i in term.out.indices if i not in sp)
            out_subs = "".join(self._letter[i] for i in out_dense)
            out_shape = tuple(dims[i] for i in out_dense)
            links.append(ChainLink(operands=tuple(link_ops),
                                   out_subs=out_subs, out_shape=out_shape))

        out_lvl = levels[-1]
        nseg_out = csf.nfib[out_lvl] if out_lvl > 0 else 1
        dtype = jnp.result_type(dtype, *[a.dtype for a in link_arrays])
        nseg_lvls = tuple(csf.nfib[l] if l > 0 else 1 for l in levels)
        ir = StageIR(kind="chain", stage=stage, links=tuple(links),
                     nseg_out=nseg_out, nseg_lvls=nseg_lvls)
        self.emitted_stages.append(stage)
        self.emitted_chains.append((stage, tuple(links)))
        self.emitted_ir.append(ir)
        out2d = self.lowering.chain(ir, segs, firsts, lasts, mask, padded,
                                    link_arrays, dtype)
        self.stage_strategy[(lvl0, out_lvl)] = "fused"
        arr = out2d.reshape((nseg_out,) + out_shape)
        if out_lvl == 0:
            return DenseVal(arr.reshape(out_shape), out_dense)
        return FiberVal(arr, out_lvl, out_dense)

    # -- the lowering unit ---------------------------------------------- #
    def _fiber_contract(self, csf: CSFArrays, fa, da, fb, db,
                        out_dense: tuple[str, ...], lvl: int,
                        out_lvl: int) -> jnp.ndarray:
        dims = self.spec.dims
        nfib = csf.nfib[lvl]
        oshape = tuple(dims[i] for i in out_dense)
        dtype = jnp.result_type(fa.dtype, fb.dtype)
        reduce_ = out_lvl < lvl

        if nfib == 0:
            if out_lvl == 0:
                return jnp.zeros(oshape, dtype)
            rows = csf.nfib[out_lvl] if reduce_ else 0
            return jnp.zeros((rows,) + oshape, dtype)

        operands, arrays = [], []
        for arr, inds in ((fa, da), (fb, db)):
            shape = tuple(dims[i] for i in inds)
            fiber = arr.ndim == len(inds) + 1
            operands.append(StageOperand(
                subs="".join(self._letter[i] for i in inds),
                shape=shape, fiber=fiber))
            arrays.append(arr)
        out_subs = "".join(self._letter[i] for i in out_dense)

        if reduce_ and self._use_row(csf, lvl, out_lvl):
            lay, gather, mask, block_seg, block_first = \
                self._layout(csf, lvl, out_lvl)
            padded = [
                arr.reshape(nfib, -1)[gather] if op.fiber
                else arr.reshape(1, -1)
                for arr, op in zip(arrays, operands)]
            stage = Stage(operands=tuple(operands), out_subs=out_subs,
                          out_shape=oshape, reduce=True, block=self.block,
                          nseg=lay.nseg, interpret=self.interpret,
                          tile=self.tile_align)
            ir = StageIR(kind="reduce", stage=stage)
            self.emitted_stages.append(stage)
            self.emitted_ir.append(ir)
            out2d = self.lowering.reduce(ir, block_seg, block_first, mask,
                                        padded, dtype)
            arr = out2d.reshape((lay.nseg,) + oshape)
            return arr.reshape(oshape) if out_lvl == 0 else arr

        # product stage: fused per-fiber contraction; sparse reduction (if
        # any) stays an XLA segmented scan over sorted CSF segment ids
        P = round_up(nfib, self.block)
        padded = []
        for arr, op in zip(arrays, operands):
            if op.fiber:
                flat = arr.reshape(nfib, -1)
                padded.append(jnp.pad(flat, ((0, P - nfib), (0, 0))))
            else:
                padded.append(arr.reshape(1, -1))
        stage = Stage(operands=tuple(operands), out_subs=out_subs,
                      out_shape=oshape, reduce=False, block=self.block,
                      nseg=0, interpret=self.interpret,
                      tile=self.tile_align)
        ir = StageIR(kind="product", stage=stage)
        self.emitted_stages.append(stage)
        self.emitted_ir.append(ir)
        per_fiber = self.lowering.product(ir, padded, dtype)
        arr = per_fiber[:nfib].reshape((nfib,) + oshape)
        if reduce_:
            seg = csf.seg[(lvl, out_lvl)] if out_lvl > 0 else jnp.zeros(
                nfib, jnp.int32)
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            arr = jax.ops.segment_sum(arr, seg, num_segments=nseg,
                                      indices_are_sorted=True)
            if out_lvl == 0:
                arr = arr[0]
        return arr
