"""PallasPlanExecutor — lower any fused SpTTN plan to Pallas kernels.

Structural sibling of :class:`~repro.core.executor.VectorizedExecutor`
(it *is* one, by inheritance): operand lifting, dense fallbacks, and
final-output materialization are shared, so the two engines agree by
construction everywhere except the lowering unit — ``_fiber_contract``,
where the XLA engine's einsum + ``segment_sum`` is replaced by generated
Pallas stages (kernels/codegen/stages.py).

Per reducing term the generator picks one of two lowerings from the
static segment profile (pattern-known, so the choice is trace-time):

* **row** — the mttkrp-style fused kernel: fibers padded per output
  segment to block multiples (``padded_segment_layout`` at arbitrary
  (lvl, out_lvl), not just leaf->root), output row accumulated in VMEM
  with the Algorithm-2 reset.  Chosen when segments are block-sized —
  padding stays bounded.
* **segsum** — a fused product stage (hadamard/dot in VMEM) followed by
  an XLA segmented sum.  Chosen when segments are tiny (e.g. leaf ->
  next level), where block-per-segment padding would explode.

Gathers stay in XLA on purpose: TPU-native big fast gathers feed the
kernels, matching the hand-written MTTKRP kernel this module retires as
a special case (it survives as the generator's regression fixture).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 default_interpret)
from repro.core.loopnest import LoopOrder
from repro.core.paths import ContractionPath
from repro.core.spec import SpTTNSpec
from repro.kernels.codegen.stages import (Stage, StageOperand,
                                          run_product_stage,
                                          run_reduce_stage)
from repro.kernels.util import padded_segment_layout, round_up

DEFAULT_BLOCK = 128


class PallasPlanExecutor(VectorizedExecutor):
    """Execute a (path, order) plan through generated Pallas kernels.

    ``strategy`` forces the reduction lowering (``"row"``/``"segsum"``)
    for tests; ``"auto"`` picks per stage from the segment profile.
    ``interpret=None`` resolves to True off-TPU (CPU validation mode).
    """

    def __init__(self, spec: SpTTNSpec, path: ContractionPath,
                 order: LoopOrder, block: int = DEFAULT_BLOCK,
                 interpret: bool | None = None, strategy: str = "auto"):
        super().__init__(spec, path, order)
        if strategy not in ("auto", "row", "segsum"):
            raise ValueError(f"unknown strategy {strategy!r}")
        self.block = block
        self.interpret = default_interpret() if interpret is None \
            else interpret
        self.strategy = strategy

    # -- static layouts (pattern-fixed, cached on the CSFArrays) -------- #
    def _layout(self, csf: CSFArrays, lvl: int, out_lvl: int):
        cache = csf.__dict__.setdefault("_codegen_layouts", {})
        key = (lvl, out_lvl, self.block)
        if key not in cache:
            seg = np.asarray(csf.seg[(lvl, out_lvl)])
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            lay = padded_segment_layout(seg, nseg, self.block)
            cache[key] = (lay, jnp.asarray(lay.gather),
                          jnp.asarray(lay.mask)[:, None],
                          jnp.asarray(lay.block_seg),
                          jnp.asarray(lay.block_first))
        return cache[key]

    def _use_row(self, csf: CSFArrays, lvl: int, out_lvl: int) -> bool:
        if self.strategy != "auto":
            return self.strategy == "row"
        nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
        nfib = csf.nfib[lvl]
        # block-per-segment padding must stay within ~4x of the fiber
        # count (small kernels always qualify via the absolute floor)
        return nseg * self.block <= max(4 * nfib, 4 * self.block)

    # -- the lowering unit ---------------------------------------------- #
    def _fiber_contract(self, csf: CSFArrays, fa, da, fb, db,
                        out_dense: tuple[str, ...], lvl: int,
                        out_lvl: int) -> jnp.ndarray:
        dims = self.spec.dims
        nfib = csf.nfib[lvl]
        oshape = tuple(dims[i] for i in out_dense)
        dtype = jnp.result_type(fa.dtype, fb.dtype)
        reduce_ = out_lvl < lvl

        if nfib == 0:
            if out_lvl == 0:
                return jnp.zeros(oshape, dtype)
            rows = csf.nfib[out_lvl] if reduce_ else 0
            return jnp.zeros((rows,) + oshape, dtype)

        operands, arrays = [], []
        for arr, inds in ((fa, da), (fb, db)):
            shape = tuple(dims[i] for i in inds)
            fiber = arr.ndim == len(inds) + 1
            operands.append(StageOperand(
                subs="".join(self._letter[i] for i in inds),
                shape=shape, fiber=fiber))
            arrays.append(arr)
        out_subs = "".join(self._letter[i] for i in out_dense)

        if reduce_ and self._use_row(csf, lvl, out_lvl):
            lay, gather, mask, block_seg, block_first = \
                self._layout(csf, lvl, out_lvl)
            padded = [
                arr.reshape(nfib, -1)[gather] if op.fiber
                else arr.reshape(1, -1)
                for arr, op in zip(arrays, operands)]
            stage = Stage(operands=tuple(operands), out_subs=out_subs,
                          out_shape=oshape, reduce=True, block=self.block,
                          nseg=lay.nseg, interpret=self.interpret)
            out2d = run_reduce_stage(stage, block_seg, block_first, mask,
                                     padded, dtype)
            arr = out2d.reshape((lay.nseg,) + oshape)
            return arr.reshape(oshape) if out_lvl == 0 else arr

        # product stage: fused per-fiber contraction; sparse reduction (if
        # any) stays an XLA segmented scan over sorted CSF segment ids
        P = round_up(nfib, self.block)
        padded = []
        for arr, op in zip(arrays, operands):
            if op.fiber:
                flat = arr.reshape(nfib, -1)
                padded.append(jnp.pad(flat, ((0, P - nfib), (0, 0))))
            else:
                padded.append(arr.reshape(1, -1))
        stage = Stage(operands=tuple(operands), out_subs=out_subs,
                      out_shape=oshape, reduce=False, block=self.block,
                      nseg=0, interpret=self.interpret)
        per_fiber = run_product_stage(stage, padded, dtype)
        arr = per_fiber[:nfib].reshape((nfib,) + oshape)
        if reduce_:
            seg = csf.seg[(lvl, out_lvl)] if out_lvl > 0 else jnp.zeros(
                nfib, jnp.int32)
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            arr = jax.ops.segment_sum(arr, seg, num_segments=nseg,
                                      indices_are_sorted=True)
            if out_lvl == 0:
                arr = arr[0]
        return arr
