"""PallasPlanExecutor — lower any fused SpTTN plan to Pallas kernels.

Structural sibling of :class:`~repro.core.executor.VectorizedExecutor`
(it *is* one, by inheritance): operand lifting, dense fallbacks, and
final-output materialization are shared, so the two engines agree by
construction everywhere except the lowering unit — ``_fiber_contract``,
where the XLA engine's einsum + ``segment_sum`` is replaced by generated
Pallas stages (kernels/codegen/stages.py).

Per reducing term the generator picks one of two lowerings from the
static segment profile (pattern-known, so the choice is trace-time):

* **row** — the mttkrp-style fused kernel: fibers padded per output
  segment to block multiples (``padded_segment_layout`` at arbitrary
  (lvl, out_lvl), not just leaf->root), output row accumulated in VMEM
  with the Algorithm-2 reset.  Chosen when segments are block-sized —
  padding stays bounded.
* **segsum** — a fused product stage (hadamard/dot in VMEM) followed by
  an XLA segmented sum.  Chosen when segments are tiny (e.g. leaf ->
  next level), where block-per-segment padding would explode.

Gathers stay in XLA on purpose: TPU-native big fast gathers feed the
kernels, matching the hand-written MTTKRP kernel this module retires as
a special case (it survives as the generator's regression fixture).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.executor import (CSFArrays, VectorizedExecutor,
                                 default_interpret)
from repro.core.loopnest import LoopOrder
from repro.core.paths import ContractionPath
from repro.core.spec import SpTTNSpec
from repro.kernels.codegen.stages import (Stage, StageOperand,
                                          run_product_stage,
                                          run_reduce_stage)
from repro.kernels.util import padded_segment_layout, round_up

DEFAULT_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class SegmentProfile:
    """Static reduction profile of one (lvl → out_lvl) CSF segment map.

    This is everything the strategy choice reads about the pattern, and it
    is computed from the *operand actually being executed* — in the
    distributed engine that is a shard's local CSF, so each shard picks
    its lowering from its own nonzero distribution (a skewed shard may
    take ``row`` while a sparse one takes ``segsum``; DESIGN.md §7).
    """

    lvl: int
    out_lvl: int
    nfib: int            # level-``lvl`` fibers entering the reduction
    nseg: int            # level-``out_lvl`` output rows
    max_seg: int         # longest segment (fibers feeding one output row)
    mean_seg: float      # nfib / nseg

    @staticmethod
    def row_decision(nfib: int, nseg: int, block: int) -> bool:
        """The strategy formula on the O(1) fiber counts alone: row wins
        when block-per-segment padding stays within ~4x of the fiber
        count (small kernels always qualify via the absolute floor)."""
        return nseg * block <= max(4 * nfib, 4 * block)

    def prefers_row(self, block: int) -> bool:
        """True when the fused VMEM row accumulator is the better
        lowering for this profile; otherwise fall back to ``segsum``."""
        return self.row_decision(self.nfib, self.nseg, block)


def segment_profile(csf: CSFArrays, lvl: int, out_lvl: int) -> SegmentProfile:
    """Profile the ``(lvl, out_lvl)`` segment map of ``csf`` (pattern-
    static; concrete per operand, hence per shard).  ``max_seg`` and
    ``mean_seg`` cost one O(nfib) pass — inspection/reporting callers
    only; the trace-time strategy choice reads just the O(1) counts."""
    nfib = csf.nfib[lvl]
    nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
    if nfib == 0:
        return SegmentProfile(lvl, out_lvl, 0, nseg, 0, 0.0)
    seg = np.asarray(csf.seg[(lvl, out_lvl)]) if out_lvl > 0 else \
        np.zeros(nfib, np.int64)
    counts = np.bincount(seg, minlength=max(nseg, 1))
    return SegmentProfile(lvl, out_lvl, nfib, nseg, int(counts.max()),
                          nfib / max(nseg, 1))


class PallasPlanExecutor(VectorizedExecutor):
    """Execute a (path, order) plan through generated Pallas kernels.

    ``strategy`` forces the reduction lowering (``"row"``/``"segsum"``)
    for tests; ``"auto"`` picks per stage from the segment profile.
    ``interpret=None`` resolves to True off-TPU (CPU validation mode).
    """

    def __init__(self, spec: SpTTNSpec, path: ContractionPath,
                 order: LoopOrder, block: int = DEFAULT_BLOCK,
                 interpret: bool | None = None, strategy: str = "auto"):
        super().__init__(spec, path, order)
        if strategy not in ("auto", "row", "segsum"):
            raise ValueError(f"unknown strategy {strategy!r}")
        self.block = block
        self.interpret = default_interpret() if interpret is None \
            else interpret
        self.strategy = strategy
        # (lvl, out_lvl) -> "row" | "segsum", recorded at trace time for
        # inspection (tests, distributed per-shard strategy reporting)
        self.stage_strategy: dict[tuple[int, int], str] = {}

    # -- static layouts (pattern-fixed, cached on the CSFArrays) -------- #
    def _layout(self, csf: CSFArrays, lvl: int, out_lvl: int):
        cache = csf.__dict__.setdefault("_codegen_layouts", {})
        key = (lvl, out_lvl, self.block)
        if key not in cache:
            seg = np.asarray(csf.seg[(lvl, out_lvl)])
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            lay = padded_segment_layout(seg, nseg, self.block)
            cache[key] = (lay, jnp.asarray(lay.gather),
                          jnp.asarray(lay.mask)[:, None],
                          jnp.asarray(lay.block_seg),
                          jnp.asarray(lay.block_first))
        return cache[key]

    def strategy_for(self, csf: CSFArrays, lvl: int, out_lvl: int) -> str:
        """Reduction lowering for this operand's (lvl, out_lvl) stage,
        chosen from its segment profile (per-shard in the distributed
        engine) unless forced by ``strategy``.  Reads only the O(1)
        fiber counts — :func:`segment_profile` exists for callers that
        want the full distribution."""
        if self.strategy != "auto":
            return self.strategy
        nfib = csf.nfib[lvl]
        nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
        row = SegmentProfile.row_decision(nfib, nseg, self.block)
        return "row" if row else "segsum"

    def _use_row(self, csf: CSFArrays, lvl: int, out_lvl: int) -> bool:
        choice = self.strategy_for(csf, lvl, out_lvl)
        self.stage_strategy[(lvl, out_lvl)] = choice
        return choice == "row"

    # -- the lowering unit ---------------------------------------------- #
    def _fiber_contract(self, csf: CSFArrays, fa, da, fb, db,
                        out_dense: tuple[str, ...], lvl: int,
                        out_lvl: int) -> jnp.ndarray:
        dims = self.spec.dims
        nfib = csf.nfib[lvl]
        oshape = tuple(dims[i] for i in out_dense)
        dtype = jnp.result_type(fa.dtype, fb.dtype)
        reduce_ = out_lvl < lvl

        if nfib == 0:
            if out_lvl == 0:
                return jnp.zeros(oshape, dtype)
            rows = csf.nfib[out_lvl] if reduce_ else 0
            return jnp.zeros((rows,) + oshape, dtype)

        operands, arrays = [], []
        for arr, inds in ((fa, da), (fb, db)):
            shape = tuple(dims[i] for i in inds)
            fiber = arr.ndim == len(inds) + 1
            operands.append(StageOperand(
                subs="".join(self._letter[i] for i in inds),
                shape=shape, fiber=fiber))
            arrays.append(arr)
        out_subs = "".join(self._letter[i] for i in out_dense)

        if reduce_ and self._use_row(csf, lvl, out_lvl):
            lay, gather, mask, block_seg, block_first = \
                self._layout(csf, lvl, out_lvl)
            padded = [
                arr.reshape(nfib, -1)[gather] if op.fiber
                else arr.reshape(1, -1)
                for arr, op in zip(arrays, operands)]
            stage = Stage(operands=tuple(operands), out_subs=out_subs,
                          out_shape=oshape, reduce=True, block=self.block,
                          nseg=lay.nseg, interpret=self.interpret)
            out2d = run_reduce_stage(stage, block_seg, block_first, mask,
                                     padded, dtype)
            arr = out2d.reshape((lay.nseg,) + oshape)
            return arr.reshape(oshape) if out_lvl == 0 else arr

        # product stage: fused per-fiber contraction; sparse reduction (if
        # any) stays an XLA segmented scan over sorted CSF segment ids
        P = round_up(nfib, self.block)
        padded = []
        for arr, op in zip(arrays, operands):
            if op.fiber:
                flat = arr.reshape(nfib, -1)
                padded.append(jnp.pad(flat, ((0, P - nfib), (0, 0))))
            else:
                padded.append(arr.reshape(1, -1))
        stage = Stage(operands=tuple(operands), out_subs=out_subs,
                      out_shape=oshape, reduce=False, block=self.block,
                      nseg=0, interpret=self.interpret)
        per_fiber = run_product_stage(stage, padded, dtype)
        arr = per_fiber[:nfib].reshape((nfib,) + oshape)
        if reduce_:
            seg = csf.seg[(lvl, out_lvl)] if out_lvl > 0 else jnp.zeros(
                nfib, jnp.int32)
            nseg = csf.nfib[out_lvl] if out_lvl > 0 else 1
            arr = jax.ops.segment_sum(arr, seg, num_segments=nseg,
                                      indices_are_sorted=True)
            if out_lvl == 0:
                arr = arr[0]
        return arr
