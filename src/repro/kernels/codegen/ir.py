"""Target-neutral stage IR — what the code generator says, not how.

The plan executor (kernels/codegen/executor.py) lowers a fused SpTTN
plan into a sequence of *stage descriptions*: pure dataclasses carrying
the operand index maps (einsum subscripts + dense shapes), the block
layout request (block size, segment-row count), the reset/flush points
of Algorithm 2 (implied by ``reduce`` / the chain's per-level segment
maps), and the einsum links of a fused chain.  Nothing in this module
touches Pallas: a :class:`StageIR` is a complete, target-independent
statement of the work, and a registered :class:`Lowering` turns it into
kernels for one target:

* ``"tpu"`` (kernels/codegen/stages.py) — the sequential-grid lowering:
  scalar-prefetched block→row index maps, a VMEM crossing buffer
  revisited across a segment's blocks with the Algorithm-2 reset, VMEM
  scratch buffers per fused-chain level.  Correct **only** because TPU
  grids execute sequentially.
* ``"gpu"`` (kernels/codegen/lower_gpu.py) — the Mosaic-GPU-style
  lowering: GPU grids guarantee no sequential execution, so the reduce
  is *split-K over segment ranges* — every block writes its own partial
  (1:1 block→output mapping, grid-parallel legal) and a final
  segment-combine pass sums partials into segment rows.

The registry is keyed by target name; ``make_executor`` maps engine
backends onto targets via
:data:`repro.analysis.diagnostics.PALLAS_TARGETS` (``"pallas"`` → tpu,
``"pallas-gpu"`` → gpu), and the static verifier's ``SPTTN-E041``
rejects a plan whose backend has no registered lowering on this host.

Tile alignment (``Stage.tile``) is part of the IR, not the lowering:
both targets honor the pad-to-tile request identically (lane widths
padded to :data:`TILE_LANE`, mask pre-folded), so a tiled stage is
bit-identical across targets too.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.kernels.util import round_up

# float32 hardware tile: (sublane, lane) = (8, 128).  Wider dtypes only
# shrink the sublane constraint, so aligning to the float32 tile is valid
# for every dtype the stages accumulate at (>= float32).
TILE_LANE = 128
TILE_SUBLANE = 8


def lane_pad(dim: int) -> int:
    """Next multiple of :data:`TILE_LANE` at or above ``dim``."""
    return round_up(dim, TILE_LANE)


@dataclasses.dataclass(frozen=True)
class StageOperand:
    """One kernel input: ``subs`` are the dense-axis einsum letters,
    ``shape`` the dense shape.  ``fiber`` operands carry the padded fiber
    axis (einsum batch letter Z) and arrive as (P, prod(shape)) blocks;
    broadcast operands arrive as one (1, prod(shape)) block shared by
    every grid step."""

    subs: str
    shape: tuple[int, ...]
    fiber: bool

    @property
    def flat_dim(self) -> int:
        return math.prod(self.shape)


def accumulator_type(dtype):
    """Accumulation dtype for a stage's in-kernel einsum: at least float32
    (MXU accumulation width), widened to match wider operands — float64
    stages accumulate at float64, never silently at float32."""
    return jnp.promote_types(jnp.float32, dtype)


@dataclasses.dataclass(frozen=True)
class Stage:
    """A single generated kernel: ``einsum(operands) -> out_subs`` per
    block, reduced over the fiber axis into ``nseg`` segment rows when
    ``reduce`` is set.  ``tile`` selects the pad-to-tile lowering (lane
    widths padded to :data:`TILE_LANE`, mask pre-folded) required for
    ``interpret=False`` on real TPUs."""

    operands: tuple[StageOperand, ...]
    out_subs: str
    out_shape: tuple[int, ...]
    reduce: bool
    block: int
    nseg: int            # segment-row count (reduce stages only)
    interpret: bool
    tile: bool = False

    @property
    def out_flat_dim(self) -> int:
        return math.prod(self.out_shape)

    def op_pad(self, op: StageOperand) -> int:
        """Lane width of ``op``'s block (padded in tile mode)."""
        return lane_pad(op.flat_dim) if self.tile else op.flat_dim

    @property
    def out_pad(self) -> int:
        """Lane width of the output block (padded in tile mode)."""
        return lane_pad(self.out_flat_dim) if self.tile else self.out_flat_dim

    @property
    def expr(self) -> str:
        ins = ",".join(("Z" + op.subs) if op.fiber else op.subs
                       for op in self.operands)
        return f"{ins}->{'' if self.reduce else 'Z'}{self.out_subs}"


@dataclasses.dataclass(frozen=True)
class ChainLink:
    """One outer level of a fused reducing chain.

    ``operands[0]`` is the inner crossing buffer (always a fiber operand:
    one level-``lvl`` row per flush); the rest are the link term's other
    operands.  ``expr`` reduces the singleton fiber axis away, so a flush
    adds one ``out_shape`` partial into the next level's buffer — how a
    target realizes the flush (in-kernel segment-close trigger on TPU,
    batched per-row einsum + segment combine on GPU) is the lowering's
    business, not the link's."""

    operands: tuple[StageOperand, ...]
    out_subs: str
    out_shape: tuple[int, ...]

    @property
    def out_flat_dim(self) -> int:
        return math.prod(self.out_shape)

    @property
    def expr(self) -> str:
        ins = ",".join(("Z" + op.subs) if op.fiber else op.subs
                       for op in self.operands)
        return f"{ins}->{self.out_subs}"


@dataclasses.dataclass(frozen=True)
class StageIR:
    """One target-neutral lowering unit, as emitted by the executor.

    ``kind`` selects the lowering entry point:

    * ``"reduce"`` — a row-strategy reducing stage: ``stage`` plus the
      block layout (``block_seg``/``block_first``/``mask``) supplied at
      lowering time.  Reset point: a segment's first block; flush point:
      a segment's last block (both implied by the layout arrays).
    * ``"product"`` — a per-fiber product stage, blocks 1:1 with output
      blocks (no cross-block state, grid-parallel on every target).
    * ``"chain"`` — a fused reducing chain: innermost ``stage`` plus
      ``links`` outward; ``nseg_lvls[j]`` is the segment-row count at
      chain level ``j`` (innermost-first), ``nseg_out`` the final row
      count (== ``nseg_lvls[-1]``).

    The IR an executor emits is identical across targets — the
    differential tests assert exactly that — so ``emitted_ir`` equality
    is the cheap witness that a lowering disagreement is a lowering bug,
    never a construction bug."""

    kind: str
    stage: Stage
    links: tuple[ChainLink, ...] = ()
    nseg_out: int = 0
    nseg_lvls: tuple[int, ...] = ()


# --------------------------------------------------------------------- #
# Shared lowering helpers (value-level, target-independent)
# --------------------------------------------------------------------- #
def _premask(stage: Stage, padded, mask):
    """Fold the pad-slot mask into the first fiber operand ahead of the
    kernel (tile mode: the ``(block, 1)`` mask input has no tile-legal
    lane width, so masking happens in XLA where a (P, 1) broadcast is
    free).  Pad slots gather nonzero 0's values — one zero factor per
    product is necessary and sufficient for their partials to vanish."""
    out = list(padded)
    for i, op in enumerate(stage.operands):
        if op.fiber:
            out[i] = out[i] * mask.astype(out[i].dtype)
            break
    return out


def _lane_padded(arr, width: int):
    """Zero-pad the last dim of a 2-D array up to ``width`` — used both on
    operand arrays ahead of the kernel and on kernel partials before they
    accumulate, so output pad lanes only ever hold zeros and the caller's
    final column slice is exact."""
    if arr.shape[-1] == width:
        return arr
    return jnp.pad(arr, ((0, 0), (0, width - arr.shape[-1])))


def _check_block_grid(padded_len: int, block: int) -> None:
    """The stage grid covers ``padded_len // block`` blocks; a
    non-multiple length would silently drop the tail slots, so fail
    loudly instead (layout producers — ``padded_segment_layout``,
    ``pad_segment_layout``, the stacked distributed padding — all
    guarantee block multiples).  Thin wrapper over the verifier's
    :func:`repro.analysis.invariants.check_block_grid` (SPTTN-E022)."""
    from repro.analysis.invariants import check_block_grid
    d = check_block_grid(padded_len, block)
    if d is not None:
        raise ValueError(f"{d.message} [{d.code}]")


def _load_operands(stage: Stage, in_refs, mask_ref):
    """Read each operand block and restore its dense shape; the mask is
    folded into the first fiber operand so pad slots contribute zero.
    Tile mode slices the padded lanes back off before the reshape, so
    the einsum always sees exact (unpadded) operands."""
    vals = []
    masked = mask_ref is None
    for ref, op in zip(in_refs, stage.operands):
        v = ref[...]
        if v.shape[-1] != op.flat_dim:
            v = v[:, :op.flat_dim]
        if op.fiber:
            v = v.reshape((stage.block,) + op.shape)
            if not masked:
                m = mask_ref[...].reshape(
                    (stage.block,) + (1,) * len(op.shape))
                v = v * m.astype(v.dtype)
                masked = True
        else:
            v = v.reshape(op.shape)
        vals.append(v)
    return vals


# --------------------------------------------------------------------- #
# Per-target lowering registry
# --------------------------------------------------------------------- #
class Lowering:
    """Contract one target implements to consume the stage IR.

    Every method receives a :class:`StageIR` plus the already-gathered
    block arrays (layouts may be traced: the stacked distributed engine
    feeds per-shard slices through the TPU lowering) and returns the
    stage's logical 2-D output:

    * ``reduce``  → ``(stage.nseg, stage.out_flat_dim)`` in ``dtype``
    * ``product`` → ``(P, stage.out_flat_dim)`` in ``dtype`` (pad rows
      included; the executor slices ``[:nfib]``)
    * ``chain``   → ``(ir.nseg_out, links[-1].out_flat_dim)`` in
      ``dtype``

    Logical output shapes are part of the contract — the hypothesis
    property test drives random nests through every registered lowering
    and asserts the shapes match.
    """

    target: str = "?"

    def reduce(self, ir: StageIR, block_seg, block_first, mask, padded,
               dtype):
        raise NotImplementedError

    def product(self, ir: StageIR, padded, dtype):
        raise NotImplementedError

    def chain(self, ir: StageIR, seg_lvls, first_lvls, last_lvls, mask,
              padded, link_arrays, dtype):
        raise NotImplementedError


_LOWERINGS: dict[str, Lowering] = {}


def register_lowering(lowering: Lowering) -> Lowering:
    """Register ``lowering`` under its ``target`` name (last wins, so a
    test can shadow and restore a target)."""
    _LOWERINGS[lowering.target] = lowering
    return lowering


def lowering_targets() -> tuple[str, ...]:
    """Registered target names, sorted (``('gpu', 'tpu')`` after the
    package import registers both built-ins)."""
    return tuple(sorted(_LOWERINGS))


def get_lowering(target: str) -> Lowering:
    """The registered lowering for ``target``; raises ``ValueError``
    naming the registered targets otherwise (the executor surfaces this
    as the verifier's SPTTN-E041)."""
    try:
        return _LOWERINGS[target]
    except KeyError:
        raise ValueError(
            f"no stage lowering registered for target {target!r} "
            f"(registered: {lowering_targets()})") from None
