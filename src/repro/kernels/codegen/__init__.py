"""Pallas code generation for arbitrary SpTTN plans (DESIGN.md §6).

Lowers any fused :class:`~repro.core.planner.SpTTNPlan` — contraction
path + loop order + CSF level profile — to fused Pallas kernels.  The
executor emits target-neutral stage IR (ir.py) and a registered
per-target lowering turns it into kernels: ``"tpu"`` (stages.py) is the
``backend="pallas"`` engine behind
:func:`repro.core.executor.make_executor`, ``"gpu"`` (lower_gpu.py) the
``backend="pallas-gpu"`` engine.  See docs/backends.md.
"""
from repro.kernels.codegen.executor import (DEFAULT_BLOCK,
                                            PallasPlanExecutor,
                                            SegmentProfile, fusible_chains,
                                            segment_profile)
from repro.kernels.codegen.ir import (TILE_LANE, TILE_SUBLANE, ChainLink,
                                      Lowering, Stage, StageIR,
                                      StageOperand, accumulator_type,
                                      get_lowering, lane_pad,
                                      lowering_targets, register_lowering)
from repro.kernels.codegen.lower_gpu import (MosaicGPULowering,
                                             segment_combine,
                                             splitk_partials)
from repro.kernels.codegen.stages import (TPULowering,
                                          run_fused_chain_stage,
                                          run_product_stage,
                                          run_reduce_stage)

__all__ = [
    "DEFAULT_BLOCK", "PallasPlanExecutor", "SegmentProfile",
    "fusible_chains", "segment_profile", "ChainLink", "Stage", "StageIR",
    "StageOperand", "Lowering", "TPULowering", "MosaicGPULowering",
    "TILE_LANE", "TILE_SUBLANE", "accumulator_type", "lane_pad",
    "get_lowering", "lowering_targets", "register_lowering",
    "segment_combine", "splitk_partials", "run_fused_chain_stage",
    "run_product_stage", "run_reduce_stage",
]
