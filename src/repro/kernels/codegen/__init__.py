"""Pallas code generation for arbitrary SpTTN plans (DESIGN.md §6).

Lowers any fused :class:`~repro.core.planner.SpTTNPlan` — contraction
path + loop order + CSF level profile — to fused Pallas kernels.  This
is the ``backend="pallas"`` engine behind
:func:`repro.core.executor.make_executor`.
"""
from repro.kernels.codegen.executor import (DEFAULT_BLOCK,
                                            PallasPlanExecutor,
                                            SegmentProfile, fusible_chains,
                                            segment_profile)
from repro.kernels.codegen.stages import (TILE_LANE, TILE_SUBLANE, ChainLink,
                                          Stage, StageOperand,
                                          accumulator_type, lane_pad,
                                          run_fused_chain_stage,
                                          run_product_stage,
                                          run_reduce_stage)

__all__ = [
    "DEFAULT_BLOCK", "PallasPlanExecutor", "SegmentProfile",
    "fusible_chains", "segment_profile", "ChainLink", "Stage",
    "StageOperand", "TILE_LANE", "TILE_SUBLANE", "accumulator_type",
    "lane_pad", "run_fused_chain_stage", "run_product_stage",
    "run_reduce_stage",
]
