"""Mosaic-GPU-style stage lowering — split-K reduce over segment ranges.

The TPU lowering (stages.py) is correct only because TPU grids execute
sequentially: the output BlockSpec revisits one segment row across all
of its blocks and a VMEM accumulator carries the partial between grid
steps, with ``block_first`` firing the Algorithm-2 reset.  GPU grids
give **no such guarantee** — programs launch in parallel and may run in
any order — so this lowering realizes the same stage IR with a
different reduce:

* **split-K partials** (:func:`splitk_partials`): every grid block
  computes its own partial over its ``block`` fibers and writes it to
  its own row of an ``(n_blocks, out_width)`` buffer.  The block→output
  mapping is 1:1 (``lambda i: (i, 0)``), so no two programs touch the
  same memory and the kernel is legal under any execution order — the
  canonical GPU split-K shape.
* **segment combine** (:func:`segment_combine`): a second pass sums each
  segment's block partials into its output row, keyed by the *same*
  ``block_seg`` array the TPU lowering scalar-prefetches.  ``block_seg``
  is sorted (padded_segment_layout emits segments in order), so the
  combine is a sorted ``segment_sum`` — and because it adds a segment's
  partials in ascending block order, it reproduces the TPU accumulator's
  addition order exactly: split-K-then-combine is **bit-for-bit** equal
  to sequential accumulation at any float width (the hypothesis suite
  asserts this on f64).

Product stages carry no cross-block state in either lowering (blocks
map 1:1 to output blocks already), so the GPU target reuses the shared
grid-parallel product kernel unchanged.

Fused chains cannot keep per-level crossing buffers resident across
grid steps without the sequential grid, so the GPU chain is *split-K at
the innermost level* plus one batched einsum + segment combine per link
(the flush of every level-``j`` row computed at once instead of at
segment close).  That trades the TPU lowering's single-kernel HBM
avoidance for legality — the chain is still one kernel launch plus
O(chain) XLA combines, and values are identical.

Pad blocks appended by the layouts (mask 0, edge-value ``block_seg``)
produce all-zero partials and combine into the final row as ``+0``, the
same inert-tail convention the stacked TPU path relies on.  This
container has no GPU, so ``interpret=True`` is the correctness witness
(PR 5's convention for TPU compiled mode); the kernels avoid every
TPU-only Pallas feature (no ``PrefetchScalarGridSpec``, no VMEM scratch,
no revisited output blocks) precisely so they stay inside the
Mosaic-GPU-expressible subset.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.codegen.ir import (Lowering, Stage, StageIR,
                                      _check_block_grid, _lane_padded,
                                      _load_operands, _premask,
                                      accumulator_type, register_lowering)
from repro.kernels.codegen.stages import run_product_stage


def splitk_partials(stage: Stage, mask, padded):
    """Per-block partials of a reducing stage: ``(n_blocks, out_flat)``
    in the accumulation dtype, one row per grid block, no cross-block
    state.  ``mask``/``padded`` follow the same conventions as
    :func:`~repro.kernels.codegen.stages.run_reduce_stage` (tile mode
    pre-folds the mask and pads lane widths)."""
    acc_t = accumulator_type(jnp.result_type(*[a.dtype for a in padded]))
    tile = stage.tile
    if tile:
        padded = _premask(stage, padded, mask)
        padded = [_lane_padded(a, stage.op_pad(op))
                  for a, op in zip(padded, stage.operands)]
    out_pad = stage.out_pad
    P = mask.shape[0]
    _check_block_grid(P, stage.block)

    def kernel(*refs):
        m_ref = None if tile else refs[0]
        in_refs = refs[(0 if tile else 1):-1]
        o_ref = refs[-1]
        vals = _load_operands(stage, in_refs, m_ref)
        part = jnp.einsum(stage.expr, *vals, preferred_element_type=acc_t)
        part = _lane_padded(part.reshape(1, stage.out_flat_dim), out_pad)
        o_ref[...] = part.astype(o_ref.dtype)

    in_specs = []
    if not tile:
        in_specs.append(pl.BlockSpec((stage.block, 1), lambda i: (i, 0)))
    for op in stage.operands:
        w = stage.op_pad(op)
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, w),
                                         lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, w), lambda i: (0, 0)))
    inputs = tuple(padded) if tile else (mask, *padded)
    out = pl.pallas_call(
        kernel,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P // stage.block, out_pad), acc_t),
        interpret=stage.interpret,
    )(*inputs)
    return out[:, :stage.out_flat_dim] if out_pad != stage.out_flat_dim \
        else out


def segment_combine(partials, seg, nseg: int):
    """Sum per-block ``partials`` rows into ``nseg`` segment rows keyed
    by the sorted block→segment map ``seg`` — the final pass of the
    split-K reduce.  Adds each segment's partials in ascending block
    order, i.e. exactly the order the TPU sequential accumulator adds
    them, so the result is bit-identical to sequential accumulation
    (not merely close): reassociation never happens, only relocation.
    ``seg`` may be traced (per-shard slices of stacked layouts)."""
    return jax.ops.segment_sum(partials, seg, num_segments=nseg,
                               indices_are_sorted=True)


def _rows_to_parents(seg_child, seg_parent, n_child: int):
    """Child-row → parent-row map derived from the per-block segment ids
    of two adjacent chain levels: every child row owns at least one
    block (CSF fibers are nonempty), and all of a child's blocks agree
    on the parent, so a last-wins scatter is exact.  Works on traced
    arrays — no host round trip."""
    return jnp.zeros((n_child,), jnp.int32).at[seg_child].set(
        seg_parent.astype(jnp.int32))


class MosaicGPULowering(Lowering):
    """The parallel-grid target: split-K partials + segment combine.
    Registered as ``"gpu"`` — the lowering behind
    ``make_executor(backend="pallas-gpu")``."""

    target = "gpu"

    def reduce(self, ir: StageIR, block_seg, block_first, mask, padded,
               dtype):
        # block_first is the TPU reset trigger; split-K has no resets —
        # the combine pass owns segment boundaries via block_seg.
        del block_first
        parts = splitk_partials(ir.stage, mask, padded)
        return segment_combine(parts, block_seg, ir.stage.nseg) \
            .astype(dtype)

    def product(self, ir: StageIR, padded, dtype):
        # 1:1 block→output products carry no cross-block state; the
        # shared grid-parallel kernel is already legal on GPU.
        return run_product_stage(ir.stage, padded, dtype)

    def chain(self, ir: StageIR, seg_lvls, first_lvls, last_lvls, mask,
              padded, link_arrays, dtype):
        del first_lvls, last_lvls    # TPU reset/flush triggers
        acc_t = accumulator_type(dtype)
        parts = splitk_partials(ir.stage, mask, padded)
        rows = segment_combine(parts, seg_lvls[0], ir.nseg_lvls[0])
        pos = 0
        for j, link in enumerate(ir.links):
            # the level-j flush, batched over all rows at once: prepend
            # the row axis Z to the link einsum's output instead of
            # reducing the singleton fiber away per segment close
            buf_op = link.operands[0]
            iv = [rows.reshape((ir.nseg_lvls[j],) + buf_op.shape)]
            ins = ["Z" + buf_op.subs]
            n_other = len(link.operands) - 1
            for op, arr in zip(link.operands[1:],
                               link_arrays[pos:pos + n_other]):
                if op.fiber:
                    iv.append(arr.reshape((ir.nseg_lvls[j],) + op.shape))
                    ins.append("Z" + op.subs)
                else:
                    iv.append(arr.reshape(op.shape))
                    ins.append(op.subs)
            pos += n_other
            expr = ",".join(ins) + "->Z" + link.out_subs
            per_row = jnp.einsum(expr, *iv, preferred_element_type=acc_t)
            parent = _rows_to_parents(seg_lvls[j], seg_lvls[j + 1],
                                      ir.nseg_lvls[j])
            rows = segment_combine(per_row.reshape(ir.nseg_lvls[j], -1),
                                   parent, ir.nseg_lvls[j + 1])
        return rows.astype(dtype)


register_lowering(MosaicGPULowering())
