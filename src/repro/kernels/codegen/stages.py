"""Pallas stage emitters — the code generator's instruction set.

A fused SpTTN plan lowers to a sequence of *stages*, one per sparse
contraction term (DESIGN.md §6).  Every stage is a scalar-prefetched
block-segment grid over level-``lvl`` CSF fibers, generalizing the
hand-written MTTKRP kernel's ``block_seg``/``block_first`` machinery
(kernels/util.py) to arbitrary CSF depth and arbitrary dense index
structure:

* the per-fiber dense contraction is one in-kernel ``jnp.einsum`` —
  traced to ``dot_general`` on the MXU (the paper's BLAS offload);
* a *reducing* stage accumulates block partials into its output-row
  crossing buffer, which lives in VMEM across the sequential grid and is
  zeroed exactly when a new segment's first block arrives — Algorithm 2's
  buffer-reset rule, keyed off the scalar-prefetched ``block_first``;
* a *product* stage keeps the fiber axis (same-level output, e.g. the
  TTTP leaf or a final scatter term) and writes blocks 1:1;
* a *fused chain* stage (:func:`run_fused_chain_stage`) lowers a whole
  chain of reducing terms sharing the sparse operand's CSF path into ONE
  kernel: per chain level a VMEM scratch buffer holds that level's
  crossing buffer, each with its own scalar-prefetched ``block_first``
  reset, and an inner buffer flushes through its link's einsum into the
  next level's buffer when its segment closes — Algorithm 2's reset rule
  applied at every depth of a single sequential grid, eliminating the
  inter-stage HBM round trip of the staged lowering.

Stages are pure descriptions (shapes, subscripts, block size); emission
happens at trace time, so one jit of the enclosing executor compiles the
whole plan.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class StageOperand:
    """One kernel input: ``subs`` are the dense-axis einsum letters,
    ``shape`` the dense shape.  ``fiber`` operands carry the padded fiber
    axis (einsum batch letter Z) and arrive as (P, prod(shape)) blocks;
    broadcast operands arrive as one (1, prod(shape)) block shared by
    every grid step."""

    subs: str
    shape: tuple[int, ...]
    fiber: bool

    @property
    def flat_dim(self) -> int:
        return math.prod(self.shape)


def accumulator_type(dtype) -> jnp.dtype:
    """Accumulation dtype for a stage's in-kernel einsum: at least float32
    (MXU accumulation width), widened to match wider operands — float64
    stages accumulate at float64, never silently at float32."""
    return jnp.promote_types(jnp.float32, dtype)


@dataclasses.dataclass(frozen=True)
class Stage:
    """A single generated kernel: ``einsum(operands) -> out_subs`` per
    block, reduced over the fiber axis into ``nseg`` segment rows when
    ``reduce`` is set."""

    operands: tuple[StageOperand, ...]
    out_subs: str
    out_shape: tuple[int, ...]
    reduce: bool
    block: int
    nseg: int            # segment-row count (reduce stages only)
    interpret: bool

    @property
    def out_flat_dim(self) -> int:
        return math.prod(self.out_shape)

    @property
    def expr(self) -> str:
        ins = ",".join(("Z" + op.subs) if op.fiber else op.subs
                       for op in self.operands)
        return f"{ins}->{'' if self.reduce else 'Z'}{self.out_subs}"


def _load_operands(stage: Stage, in_refs, mask_ref):
    """Read each operand block and restore its dense shape; the mask is
    folded into the first fiber operand so pad slots contribute zero."""
    vals = []
    masked = mask_ref is None
    for ref, op in zip(in_refs, stage.operands):
        v = ref[...]
        if op.fiber:
            v = v.reshape((stage.block,) + op.shape)
            if not masked:
                m = mask_ref[...].reshape(
                    (stage.block,) + (1,) * len(op.shape))
                v = v * m.astype(v.dtype)
                masked = True
        else:
            v = v.reshape(op.shape)
        vals.append(v)
    return vals


def run_reduce_stage(stage: Stage, block_seg: jnp.ndarray,
                     block_first: jnp.ndarray, mask: jnp.ndarray,
                     padded, dtype) -> jnp.ndarray:
    """Fused contract-and-accumulate: grid over padded fiber blocks, output
    row (the crossing buffer) resident in VMEM and revisited across its
    blocks; ``block_first`` fires the Algorithm-2 reset."""

    acc_t = accumulator_type(dtype)

    def kernel(bs_ref, bf_ref, m_ref, *refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        b = pl.program_id(0)

        @pl.when(bf_ref[b] == 1)
        def _reset():
            o_ref[...] = jnp.zeros_like(o_ref)

        vals = _load_operands(stage, in_refs, m_ref)
        part = jnp.einsum(stage.expr, *vals,
                          preferred_element_type=acc_t)
        o_ref[...] += part.reshape(1, stage.out_flat_dim).astype(o_ref.dtype)

    P = mask.shape[0]
    in_specs = [pl.BlockSpec((stage.block, 1), lambda i, bs, bf: (i, 0))]
    for op in stage.operands:
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, op.flat_dim),
                                         lambda i, bs, bf: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, op.flat_dim),
                                         lambda i, bs, bf: (0, 0)))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, stage.out_flat_dim),
                               lambda i, bs, bf: (bs[i], 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((stage.nseg, stage.out_flat_dim),
                                       dtype),
        interpret=stage.interpret,
    )(block_seg, block_first, mask, *padded)


def run_product_stage(stage: Stage, padded, dtype) -> jnp.ndarray:
    """Per-fiber fused product (no sparse reduction): blocks map 1:1 to
    output blocks; pad rows are sliced off by the caller."""

    acc_t = accumulator_type(dtype)

    def kernel(*refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        vals = _load_operands(stage, in_refs, None)
        part = jnp.einsum(stage.expr, *vals,
                          preferred_element_type=acc_t)
        o_ref[...] = part.reshape(stage.block,
                                  stage.out_flat_dim).astype(o_ref.dtype)

    P = next(a.shape[0] for a, op in zip(padded, stage.operands) if op.fiber)
    in_specs = []
    for op in stage.operands:
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, op.flat_dim),
                                         lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, op.flat_dim),
                                         lambda i: (0, 0)))
    return pl.pallas_call(
        kernel,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((stage.block, stage.out_flat_dim),
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, stage.out_flat_dim), dtype),
        interpret=stage.interpret,
    )(*padded)


@dataclasses.dataclass(frozen=True)
class ChainLink:
    """One outer level of a fused reducing chain.

    ``operands[0]`` is the inner crossing buffer (always a fiber operand:
    one level-``lvl`` row per flush); the rest are the link term's other
    operands — fiber operands arrive as scalar-prefetch-indexed ``(1, D)``
    blocks (the row of the level-``lvl`` fiber whose segment just closed),
    broadcast operands as shared ``(1, D)`` blocks.  ``expr`` reduces the
    singleton fiber axis away, so a flush adds one ``out_shape`` partial
    into the next level's buffer.
    """

    operands: tuple[StageOperand, ...]
    out_subs: str
    out_shape: tuple[int, ...]

    @property
    def out_flat_dim(self) -> int:
        return math.prod(self.out_shape)

    @property
    def expr(self) -> str:
        ins = ",".join(("Z" + op.subs) if op.fiber else op.subs
                       for op in self.operands)
        return f"{ins}->{self.out_subs}"


def run_fused_chain_stage(stage: Stage, links: tuple[ChainLink, ...],
                          seg_lvls, first_lvls, last_lvls,
                          mask: jnp.ndarray, padded, link_arrays,
                          nseg_out: int, dtype) -> jnp.ndarray:
    """One kernel for a whole chain of reducing terms (Algorithm 2 at
    every depth of a single sequential grid).

    The innermost ``stage`` accumulates block partials into the first
    VMEM scratch buffer; when level ``k``'s segment closes
    (``last_lvls[k]``), buffer ``k`` flushes through ``links[k]``'s
    einsum into buffer ``k+1`` (the last link flushes into the kernel
    output row, whose BlockSpec follows the outermost segment map).
    Per-level ``first_lvls[k]`` fires that buffer's Algorithm-2 reset.
    Segment maps are nested (CSF levels), so an outer segment's first
    block is also an inner segment's first block and flush order
    inner-to-outer within one grid step is exact.

    ``seg_lvls[k]`` is the per-block segment id at chain level ``k`` —
    levels ``0..C-2`` drive the link operands' scalar-prefetched index
    maps, level ``C-1`` drives the output BlockSpec.
    """
    C = len(links) + 1           # chain length in terms
    acc_t = accumulator_type(dtype)
    nsc = 3 * C - 1              # C segs + C firsts + (C-1) lasts
    out_flat = links[-1].out_flat_dim
    n_stage = len(stage.operands)

    def kernel(*refs):
        segs = refs[:C]
        firsts = refs[C:2 * C]
        lasts = refs[2 * C:nsc]
        del segs                 # index maps consume them; kernel does not
        m_ref = refs[nsc]
        in_refs = refs[nsc + 1:nsc + 1 + n_stage]
        link_refs = refs[nsc + 1 + n_stage:-1 - (C - 1)]
        o_ref = refs[-1 - (C - 1)]
        bufs = refs[len(refs) - (C - 1):]
        b = pl.program_id(0)

        for j in range(C - 1):
            @pl.when(firsts[j][b] == 1)
            def _reset(buf=bufs[j]):
                buf[...] = jnp.zeros_like(buf)

        @pl.when(firsts[C - 1][b] == 1)
        def _reset_out():
            o_ref[...] = jnp.zeros_like(o_ref)

        vals = _load_operands(stage, in_refs, m_ref)
        part = jnp.einsum(stage.expr, *vals, preferred_element_type=acc_t)
        bufs[0][...] += part.reshape(1, stage.out_flat_dim)

        pos = 0
        for j, link in enumerate(links):
            dst = bufs[j + 1] if j + 1 < C - 1 else o_ref
            others = link_refs[pos:pos + len(link.operands) - 1]
            pos += len(link.operands) - 1

            @pl.when(lasts[j][b] == 1)
            def _flush(j=j, link=link, dst=dst, others=others):
                iv = [bufs[j][...].reshape((1,) + link.operands[0].shape)]
                for ref, op in zip(others, link.operands[1:]):
                    v = ref[...]
                    iv.append(v.reshape(((1,) + op.shape) if op.fiber
                                        else op.shape))
                out = jnp.einsum(link.expr, *iv,
                                 preferred_element_type=acc_t)
                dst[...] += out.reshape(1, link.out_flat_dim).astype(
                    dst.dtype)

    P = mask.shape[0]
    in_specs = [pl.BlockSpec((stage.block, 1), lambda i, *s: (i, 0))]
    for op in stage.operands:
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, op.flat_dim),
                                         lambda i, *s: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, op.flat_dim),
                                         lambda i, *s: (0, 0)))
    for j, link in enumerate(links):
        for op in link.operands[1:]:
            if op.fiber:
                in_specs.append(pl.BlockSpec(
                    (1, op.flat_dim), lambda i, *s, j=j: (s[j][i], 0)))
            else:
                in_specs.append(pl.BlockSpec((1, op.flat_dim),
                                             lambda i, *s: (0, 0)))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsc,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_flat),
                               lambda i, *s: (s[C - 1][i], 0)),
        scratch_shapes=[
            pltpu.VMEM((1, link.operands[0].flat_dim), acc_t)
            for link in links],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((nseg_out, out_flat), dtype),
        interpret=stage.interpret,
    )(*seg_lvls, *first_lvls, *last_lvls, mask, *padded, *link_arrays)
