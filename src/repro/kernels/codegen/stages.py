"""Pallas stage emitters — the code generator's instruction set.

A fused SpTTN plan lowers to a sequence of *stages*, one per sparse
contraction term (DESIGN.md §6).  Every stage is a scalar-prefetched
block-segment grid over level-``lvl`` CSF fibers, generalizing the
hand-written MTTKRP kernel's ``block_seg``/``block_first`` machinery
(kernels/util.py) to arbitrary CSF depth and arbitrary dense index
structure:

* the per-fiber dense contraction is one in-kernel ``jnp.einsum`` —
  traced to ``dot_general`` on the MXU (the paper's BLAS offload);
* a *reducing* stage accumulates block partials into its output-row
  crossing buffer, which lives in VMEM across the sequential grid and is
  zeroed exactly when a new segment's first block arrives — Algorithm 2's
  buffer-reset rule, keyed off the scalar-prefetched ``block_first``;
* a *product* stage keeps the fiber axis (same-level output, e.g. the
  TTTP leaf or a final scatter term) and writes blocks 1:1.

Stages are pure descriptions (shapes, subscripts, block size); emission
happens at trace time, so one jit of the enclosing executor compiles the
whole plan.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class StageOperand:
    """One kernel input: ``subs`` are the dense-axis einsum letters,
    ``shape`` the dense shape.  ``fiber`` operands carry the padded fiber
    axis (einsum batch letter Z) and arrive as (P, prod(shape)) blocks;
    broadcast operands arrive as one (1, prod(shape)) block shared by
    every grid step."""

    subs: str
    shape: tuple[int, ...]
    fiber: bool

    @property
    def flat_dim(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class Stage:
    """A single generated kernel: ``einsum(operands) -> out_subs`` per
    block, reduced over the fiber axis into ``nseg`` segment rows when
    ``reduce`` is set."""

    operands: tuple[StageOperand, ...]
    out_subs: str
    out_shape: tuple[int, ...]
    reduce: bool
    block: int
    nseg: int            # segment-row count (reduce stages only)
    interpret: bool

    @property
    def out_flat_dim(self) -> int:
        return math.prod(self.out_shape)

    @property
    def expr(self) -> str:
        ins = ",".join(("Z" + op.subs) if op.fiber else op.subs
                       for op in self.operands)
        return f"{ins}->{'' if self.reduce else 'Z'}{self.out_subs}"


def _load_operands(stage: Stage, in_refs, mask_ref):
    """Read each operand block and restore its dense shape; the mask is
    folded into the first fiber operand so pad slots contribute zero."""
    vals = []
    masked = mask_ref is None
    for ref, op in zip(in_refs, stage.operands):
        v = ref[...]
        if op.fiber:
            v = v.reshape((stage.block,) + op.shape)
            if not masked:
                m = mask_ref[...].reshape(
                    (stage.block,) + (1,) * len(op.shape))
                v = v * m.astype(v.dtype)
                masked = True
        else:
            v = v.reshape(op.shape)
        vals.append(v)
    return vals


def run_reduce_stage(stage: Stage, block_seg: jnp.ndarray,
                     block_first: jnp.ndarray, mask: jnp.ndarray,
                     padded, dtype) -> jnp.ndarray:
    """Fused contract-and-accumulate: grid over padded fiber blocks, output
    row (the crossing buffer) resident in VMEM and revisited across its
    blocks; ``block_first`` fires the Algorithm-2 reset."""

    def kernel(bs_ref, bf_ref, m_ref, *refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        b = pl.program_id(0)

        @pl.when(bf_ref[b] == 1)
        def _reset():
            o_ref[...] = jnp.zeros_like(o_ref)

        vals = _load_operands(stage, in_refs, m_ref)
        part = jnp.einsum(stage.expr, *vals,
                          preferred_element_type=jnp.float32)
        o_ref[...] += part.reshape(1, stage.out_flat_dim).astype(o_ref.dtype)

    P = mask.shape[0]
    in_specs = [pl.BlockSpec((stage.block, 1), lambda i, bs, bf: (i, 0))]
    for op in stage.operands:
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, op.flat_dim),
                                         lambda i, bs, bf: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, op.flat_dim),
                                         lambda i, bs, bf: (0, 0)))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, stage.out_flat_dim),
                               lambda i, bs, bf: (bs[i], 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((stage.nseg, stage.out_flat_dim),
                                       dtype),
        interpret=stage.interpret,
    )(block_seg, block_first, mask, *padded)


def run_product_stage(stage: Stage, padded, dtype) -> jnp.ndarray:
    """Per-fiber fused product (no sparse reduction): blocks map 1:1 to
    output blocks; pad rows are sliced off by the caller."""

    def kernel(*refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        vals = _load_operands(stage, in_refs, None)
        part = jnp.einsum(stage.expr, *vals,
                          preferred_element_type=jnp.float32)
        o_ref[...] = part.reshape(stage.block,
                                  stage.out_flat_dim).astype(o_ref.dtype)

    P = next(a.shape[0] for a, op in zip(padded, stage.operands) if op.fiber)
    in_specs = []
    for op in stage.operands:
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, op.flat_dim),
                                         lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, op.flat_dim),
                                         lambda i: (0, 0)))
    return pl.pallas_call(
        kernel,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((stage.block, stage.out_flat_dim),
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, stage.out_flat_dim), dtype),
        interpret=stage.interpret,
    )(*padded)
