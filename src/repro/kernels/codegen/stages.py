"""TPU stage lowering — the sequential-grid consumer of the stage IR.

The target-neutral stage descriptions live in kernels/codegen/ir.py
(:class:`Stage`, :class:`ChainLink`, :class:`StageIR`); this module is
the ``"tpu"`` :class:`~repro.kernels.codegen.ir.Lowering` registered for
them, plus the runner functions it is built from (kept as public API —
tests and the stacked distributed engine call them directly).

A fused SpTTN plan lowers to a sequence of *stages*, one per sparse
contraction term (DESIGN.md §6).  On TPU every stage is a
scalar-prefetched block-segment grid over level-``lvl`` CSF fibers,
generalizing the hand-written MTTKRP kernel's
``block_seg``/``block_first`` machinery (kernels/util.py) to arbitrary
CSF depth and arbitrary dense index structure:

* the per-fiber dense contraction is one in-kernel ``jnp.einsum`` —
  traced to ``dot_general`` on the MXU (the paper's BLAS offload);
* a *reducing* stage accumulates block partials into its output-row
  crossing buffer, which lives in VMEM across the sequential grid and is
  zeroed exactly when a new segment's first block arrives — Algorithm 2's
  buffer-reset rule, keyed off the scalar-prefetched ``block_first``;
* a *product* stage keeps the fiber axis (same-level output, e.g. the
  TTTP leaf or a final scatter term) and writes blocks 1:1;
* a *fused chain* stage (:func:`run_fused_chain_stage`) lowers a whole
  chain of reducing terms sharing the sparse operand's CSF path into ONE
  kernel: per chain level a VMEM scratch buffer holds that level's
  crossing buffer, each with its own scalar-prefetched ``block_first``
  reset, and an inner buffer flushes through its link's einsum into the
  next level's buffer when its segment closes — Algorithm 2's reset rule
  applied at every depth of a single sequential grid, eliminating the
  inter-stage HBM round trip of the staged lowering.

Stages are pure descriptions (shapes, subscripts, block size); emission
happens at trace time, so one jit of the enclosing executor compiles the
whole plan.  All of this is correct *only because TPU grids execute
sequentially* — the output BlockSpec revisits a segment's row across its
blocks and the VMEM accumulator survives between grid steps.  The GPU
lowering (kernels/codegen/lower_gpu.py) makes no such assumption and
realizes the same IR as split-K partials plus a segment-combine pass.

Tile alignment (compiled mode, DESIGN.md §8)
--------------------------------------------
Real TPUs constrain VMEM blocks to hardware tiles: the last (lane)
dimension must be a multiple of :data:`TILE_LANE` (128) and the
second-to-last (sublane) dimension a multiple of :data:`TILE_SUBLANE`
(8) for float32.  ``Stage.tile`` turns on the pad-to-tile lowering:

* every operand/output block's flattened dense width is zero-padded up
  to the next lane multiple (``Stage.op_pad`` / ``Stage.out_pad``); the
  kernel slices the real width back out before the einsum, so padded
  lanes never enter the contraction and the result is bit-identical to
  the unpadded lowering;
* the ``(block, 1)`` pad-slot mask input — whose lane width cannot be
  tile-aligned without 128x waste — is folded into the first fiber
  operand *before* the kernel (:func:`_premask`), so padded rows and
  zero-nnz segment tails still contribute exact zeros;
* callers must supply ``block`` as a multiple of :data:`TILE_SUBLANE`
  (the executor rounds up; the autotuner sweeps aligned blocks only).

The pass changes only shapes, never values, so interpret mode with
``tile=True`` is the CPU-testable witness for the compiled lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The IR layer moved to kernels/codegen/ir.py; the names are re-exported
# here because this module has always been their import surface (tests,
# the stacked distributed engine, and the executor all import from
# ``stages``) and because the TPU runners below are their first consumer.
from repro.kernels.codegen.ir import (TILE_LANE, TILE_SUBLANE,  # noqa: F401
                                      ChainLink, Lowering, Stage, StageIR,
                                      StageOperand, _check_block_grid,
                                      _lane_padded, _load_operands,
                                      _premask, accumulator_type, lane_pad,
                                      register_lowering)


def run_reduce_stage(stage: Stage, block_seg: jnp.ndarray,
                     block_first: jnp.ndarray, mask: jnp.ndarray,
                     padded, dtype) -> jnp.ndarray:
    """Fused contract-and-accumulate: grid over padded fiber blocks, output
    row (the crossing buffer) resident in VMEM and revisited across its
    blocks; ``block_first`` fires the Algorithm-2 reset.

    ``block_seg``/``block_first``/``mask`` may be traced values, not just
    host constants: the stacked distributed engine feeds per-shard slices
    of mesh-stacked layouts through here so one trace serves every shard.
    Only the grid extent must be static — the index maps (``bs[i]``)
    handle dynamic block→row assignment.  Inert trailing blocks appended
    by cross-shard padding (mask 0, ``block_first`` 0, edge-value
    ``block_seg``) revisit the final output row and add zero, so the
    revisit runs of the output BlockSpec stay contiguous.
    """

    acc_t = accumulator_type(dtype)
    tile = stage.tile
    if tile:
        padded = _premask(stage, padded, mask)
        padded = [_lane_padded(a, stage.op_pad(op))
                  for a, op in zip(padded, stage.operands)]
    out_pad = stage.out_pad
    _check_block_grid(mask.shape[0], stage.block)

    def kernel(bs_ref, bf_ref, *refs):
        m_ref = None if tile else refs[0]
        in_refs = refs[(0 if tile else 1):-1]
        o_ref = refs[-1]
        b = pl.program_id(0)

        @pl.when(bf_ref[b] == 1)
        def _reset():
            o_ref[...] = jnp.zeros_like(o_ref)

        vals = _load_operands(stage, in_refs, m_ref)
        part = jnp.einsum(stage.expr, *vals,
                          preferred_element_type=acc_t)
        part = _lane_padded(part.reshape(1, stage.out_flat_dim), out_pad)
        o_ref[...] += part.astype(o_ref.dtype)

    P = mask.shape[0]
    in_specs = []
    if not tile:
        in_specs.append(pl.BlockSpec((stage.block, 1),
                                     lambda i, bs, bf: (i, 0)))
    for op in stage.operands:
        w = stage.op_pad(op)
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, w),
                                         lambda i, bs, bf: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, w),
                                         lambda i, bs, bf: (0, 0)))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_pad),
                               lambda i, bs, bf: (bs[i], 0)),
    )
    inputs = tuple(padded) if tile else (mask, *padded)
    out = pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((stage.nseg, out_pad), dtype),
        interpret=stage.interpret,
    )(block_seg, block_first, *inputs)
    return out[:, :stage.out_flat_dim] if out_pad != stage.out_flat_dim \
        else out


def run_product_stage(stage: Stage, padded, dtype) -> jnp.ndarray:
    """Per-fiber fused product (no sparse reduction): blocks map 1:1 to
    output blocks; pad rows are sliced off by the caller."""

    acc_t = accumulator_type(dtype)
    if stage.tile:
        padded = [_lane_padded(a, stage.op_pad(op))
                  for a, op in zip(padded, stage.operands)]
    out_pad = stage.out_pad

    def kernel(*refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        vals = _load_operands(stage, in_refs, None)
        part = jnp.einsum(stage.expr, *vals,
                          preferred_element_type=acc_t)
        part = _lane_padded(part.reshape(stage.block, stage.out_flat_dim),
                            out_pad)
        o_ref[...] = part.astype(o_ref.dtype)

    P = next(a.shape[0] for a, op in zip(padded, stage.operands) if op.fiber)
    _check_block_grid(P, stage.block)
    in_specs = []
    for op in stage.operands:
        w = stage.op_pad(op)
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, w),
                                         lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, w),
                                         lambda i: (0, 0)))
    out = pl.pallas_call(
        kernel,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((stage.block, out_pad),
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, out_pad), dtype),
        interpret=stage.interpret,
    )(*padded)
    return out[:, :stage.out_flat_dim] if out_pad != stage.out_flat_dim \
        else out


def run_fused_chain_stage(stage: Stage, links: tuple[ChainLink, ...],
                          seg_lvls, first_lvls, last_lvls,
                          mask: jnp.ndarray, padded, link_arrays,
                          nseg_out: int, dtype) -> jnp.ndarray:
    """One kernel for a whole chain of reducing terms (Algorithm 2 at
    every depth of a single sequential grid).

    The innermost ``stage`` accumulates block partials into the first
    VMEM scratch buffer; when level ``k``'s segment closes
    (``last_lvls[k]``), buffer ``k`` flushes through ``links[k]``'s
    einsum into buffer ``k+1`` (the last link flushes into the kernel
    output row, whose BlockSpec follows the outermost segment map).
    Per-level ``first_lvls[k]`` fires that buffer's Algorithm-2 reset.
    Segment maps are nested (CSF levels), so an outer segment's first
    block is also an inner segment's first block and flush order
    inner-to-outer within one grid step is exact.

    ``seg_lvls[k]`` is the per-block segment id at chain level ``k`` —
    levels ``0..C-2`` drive the link operands' scalar-prefetched index
    maps, level ``C-1`` drives the output BlockSpec.

    Under ``stage.tile`` every operand/buffer/output lane width is padded
    to :data:`TILE_LANE` (sliced back before each einsum) and the mask is
    pre-folded into the innermost fiber operands, exactly as in the
    single-stage runners.
    """
    C = len(links) + 1           # chain length in terms
    acc_t = accumulator_type(dtype)
    nsc = 3 * C - 1              # C segs + C firsts + (C-1) lasts
    tile = stage.tile
    out_flat = links[-1].out_flat_dim
    out_pad = lane_pad(out_flat) if tile else out_flat
    n_stage = len(stage.operands)
    link_ops_flat = [op for link in links for op in link.operands[1:]]
    # per-level crossing-buffer lane widths (scratch shapes + flush pads)
    buf_w = [lane_pad(link.operands[0].flat_dim) if tile
             else link.operands[0].flat_dim for link in links]
    if tile:
        padded = _premask(stage, padded, mask)
        padded = [_lane_padded(a, stage.op_pad(op))
                  for a, op in zip(padded, stage.operands)]
        link_arrays = [_lane_padded(a, lane_pad(op.flat_dim))
                       for a, op in zip(link_arrays, link_ops_flat)]

    def kernel(*refs):
        # refs[:C] are the segment refs; index maps consume them, the
        # kernel body never reads them directly
        firsts = refs[C:2 * C]
        lasts = refs[2 * C:nsc]
        off = nsc if tile else nsc + 1
        m_ref = None if tile else refs[nsc]
        in_refs = refs[off:off + n_stage]
        link_refs = refs[off + n_stage:-1 - (C - 1)]
        o_ref = refs[-1 - (C - 1)]
        bufs = refs[len(refs) - (C - 1):]
        b = pl.program_id(0)

        for j in range(C - 1):
            @pl.when(firsts[j][b] == 1)
            def _reset(buf=bufs[j]):
                buf[...] = jnp.zeros_like(buf)

        @pl.when(firsts[C - 1][b] == 1)
        def _reset_out():
            o_ref[...] = jnp.zeros_like(o_ref)

        vals = _load_operands(stage, in_refs, m_ref)
        part = jnp.einsum(stage.expr, *vals, preferred_element_type=acc_t)
        part = _lane_padded(part.reshape(1, stage.out_flat_dim), buf_w[0])
        bufs[0][...] += part

        pos = 0
        for j, link in enumerate(links):
            dst = bufs[j + 1] if j + 1 < C - 1 else o_ref
            dst_w = buf_w[j + 1] if j + 1 < C - 1 else out_pad
            others = link_refs[pos:pos + len(link.operands) - 1]
            pos += len(link.operands) - 1

            @pl.when(lasts[j][b] == 1)
            def _flush(j=j, link=link, dst=dst, dst_w=dst_w, others=others):
                bv = bufs[j][...]
                if bv.shape[-1] != link.operands[0].flat_dim:
                    bv = bv[:, :link.operands[0].flat_dim]
                iv = [bv.reshape((1,) + link.operands[0].shape)]
                for ref, op in zip(others, link.operands[1:]):
                    v = ref[...]
                    if v.shape[-1] != op.flat_dim:
                        v = v[:, :op.flat_dim]
                    iv.append(v.reshape(((1,) + op.shape) if op.fiber
                                        else op.shape))
                out = jnp.einsum(link.expr, *iv,
                                 preferred_element_type=acc_t)
                out = _lane_padded(out.reshape(1, link.out_flat_dim), dst_w)
                dst[...] += out.astype(dst.dtype)

    P = mask.shape[0]
    _check_block_grid(P, stage.block)
    in_specs = []
    if not tile:
        in_specs.append(pl.BlockSpec((stage.block, 1), lambda i, *s: (i, 0)))
    for op in stage.operands:
        w = stage.op_pad(op)
        if op.fiber:
            in_specs.append(pl.BlockSpec((stage.block, w),
                                         lambda i, *s: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((1, w),
                                         lambda i, *s: (0, 0)))
    for j, link in enumerate(links):
        for op in link.operands[1:]:
            w = lane_pad(op.flat_dim) if tile else op.flat_dim
            if op.fiber:
                in_specs.append(pl.BlockSpec(
                    (1, w), lambda i, *s, j=j: (s[j][i], 0)))
            else:
                in_specs.append(pl.BlockSpec((1, w),
                                             lambda i, *s: (0, 0)))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsc,
        grid=(P // stage.block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_pad),
                               lambda i, *s: (s[C - 1][i], 0)),
        scratch_shapes=[pltpu.VMEM((1, w), acc_t) for w in buf_w],
    )
    inputs = (*padded, *link_arrays) if tile else (mask, *padded,
                                                   *link_arrays)
    out = pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((nseg_out, out_pad), dtype),
        interpret=stage.interpret,
    )(*seg_lvls, *first_lvls, *last_lvls, *inputs)
    # an output row whose segment owns no block is never stored by the
    # kernel (the revisit pattern only reaches segments present in the
    # outermost block->segment map), so it returns whatever memory
    # backed the buffer.  Single-device CSF layouts reach every row, but
    # the stacked engine's shards padded to the mesh-wide maximum (and
    # its all-padding empty shards) do not — mask those rows to the
    # exact zero an empty segment contributes.
    # (jnp.where, not a multiply — the garbage may be NaN/inf, which a
    # zero multiply would propagate instead of clearing)
    row_written = jnp.zeros((nseg_out,), jnp.int32).at[
        jnp.asarray(seg_lvls[-1])].set(1)
    out = jnp.where(row_written[:, None] != 0, out, jnp.zeros((), dtype))
    return out[:, :out_flat] if out_pad != out_flat else out


class TPULowering(Lowering):
    """The sequential-grid target: adapts :class:`StageIR` onto the
    runner functions above.  Registered as ``"tpu"`` — the lowering
    behind ``make_executor(backend="pallas")``."""

    target = "tpu"

    def reduce(self, ir: StageIR, block_seg, block_first, mask, padded,
               dtype):
        return run_reduce_stage(ir.stage, block_seg, block_first, mask,
                                padded, dtype)

    def product(self, ir: StageIR, padded, dtype):
        return run_product_stage(ir.stage, padded, dtype)

    def chain(self, ir: StageIR, seg_lvls, first_lvls, last_lvls, mask,
              padded, link_arrays, dtype):
        return run_fused_chain_stage(ir.stage, ir.links, seg_lvls,
                                     first_lvls, last_lvls, mask, padded,
                                     link_arrays, ir.nseg_out, dtype)


register_lowering(TPULowering())
