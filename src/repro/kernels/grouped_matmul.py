"""Pallas TPU kernel: expert-grouped GEMM for MoE dispatch/combine.

This is the lowered form of the SpTTN plan for the MoE combine kernel
(DESIGN.md §4): the sparse top-k routing tensor is factorized into a
sort/capacity dispatch (static-shape gather) + a *dense batched GEMM over
experts* — the factorize-and-fuse schedule the planner picks over the
"unfactorized" dense one-hot einsum.

y[e] = x[e] @ w[e], x (E, C, D), w (E, D, F) — tiled over (E, C, F, D)
with a VMEM accumulator over the D grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc, *, nd: int):
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # fp32 MXU accumulation

    @pl.when(kd == nd - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)[None]


def grouped_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray,
                          bc: int = 128, bf: int = 128, bd: int = 512,
                          interpret: bool = True) -> jnp.ndarray:
    """x (E, C, D) @ w (E, D, F) -> (E, C, F).

    Block sizes default to MXU-aligned tiles; VMEM per step =
    (bc*bd + bd*bf + bc*bf) * 4B = 128*512*2*4 + 64KiB ≈ 576 KiB.
    """
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bd = min(bc, C), min(bf, F), min(bd, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0
    grid = (E, C // bc, F // bf, D // bd)
    return pl.pallas_call(
        functools.partial(_kernel, nd=D // bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
