"""jit'd wrappers for the Pallas kernels, including the host-side static
layout plumbing from CSF structures (computed once per sparsity pattern).

Every op has the same signature contract: `*_op(...)` takes device arrays +
a static layout and returns the kernel result; `use_pallas=False` falls
back to the pure-jnp reference (the XLA path used on CPU and in the
dry-run; the Pallas path is the TPU target, validated via interpret=True).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.local_attn import local_attn_pallas
from repro.kernels.mttkrp import mttkrp_pallas
from repro.kernels.rglru import rglru_pallas
from repro.kernels.ttmc import ttmc_pallas
from repro.kernels.tttp import tttp_pallas
from repro.kernels.util import PaddedSegments, padded_segment_layout
from repro.kernels.wkv6 import wkv6_pallas
from repro.sparse.csf import CSFTensor, level_segments


# --------------------------------------------------------------------------- #
# layouts
# --------------------------------------------------------------------------- #
def mttkrp_layout(csf: CSFTensor, block: int = 256) -> PaddedSegments:
    """Pad nonzeros per output row (mode-0 slice) to block multiples."""
    seg1 = level_segments(csf, csf.order, 1)
    return padded_segment_layout(seg1, csf.nfib[1], block)


def ttmc_fiber_layout(csf: CSFTensor, block: int = 128) -> PaddedSegments:
    """Pad level-2 fibers per output row to block multiples."""
    seg = level_segments(csf, 2, 1)
    return padded_segment_layout(seg, csf.nfib[1], block)


# --------------------------------------------------------------------------- #
# MTTKRP:  A(i,a) = sum_jk T(i,j,k) B(j,a) C(k,a)
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("nseg", "block", "interpret"))
def mttkrp_op(vals, jidx, kidx, b, c, gather, mask, block_seg, block_first,
              nseg: int, block: int = 256, interpret: bool = True):
    """vals/jidx/kidx are leaf-level CSF arrays; gather/mask/* from layout.
    Factor rows are gathered by XLA into the padded layout; the kernel
    fuses mask * vals * B[j] * C[k] + per-row reduction in VMEM."""
    bg = b[jidx[gather]]  # (P, R) XLA gather straight into padded layout
    cg = c[kidx[gather]]
    vp = vals[gather]
    return mttkrp_pallas(vp[:, None], bg, cg, mask[:, None],
                         block_seg, block_first, nseg, block=block,
                         interpret=interpret)


def mttkrp(csf: CSFTensor, b: jnp.ndarray, c: jnp.ndarray,
           layout: PaddedSegments | None = None, block: int = 256,
           use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """Convenience driver (gathers + kernel) for the order-3 MTTKRP leaf."""
    jidx = jnp.asarray(csf.fiber_coords(csf.order)[:, 1])
    kidx = jnp.asarray(csf.fiber_coords(csf.order)[:, 2])
    vals = jnp.asarray(csf.values)
    if not use_pallas:
        seg1 = jnp.asarray(level_segments(csf, csf.order, 1))
        return ref.mttkrp_ref(vals, b[jidx], c[kidx], seg1, csf.nfib[1])
    layout = layout or mttkrp_layout(csf, block)
    return mttkrp_op(vals, jidx, kidx, b, c,
                     jnp.asarray(layout.gather), jnp.asarray(layout.mask),
                     jnp.asarray(layout.block_seg),
                     jnp.asarray(layout.block_first),
                     nseg=layout.nseg, block=layout.block,
                     interpret=interpret)


# --------------------------------------------------------------------------- #
# TTMc fiber stage:  OUT[i] += U[j_f]^T ⊗ X[f]   over level-2 fibers f
# --------------------------------------------------------------------------- #
def ttmc_fiber(ug: jnp.ndarray, xf: jnp.ndarray, layout: PaddedSegments,
               use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        # layout.gather maps padded slots -> fiber ids; recover seg per slot
        seg = jnp.asarray(np.repeat(layout.block_seg, layout.block))
        return ref.ttmc_fiber_ref(xf[jnp.asarray(layout.gather)]
                                  * jnp.asarray(layout.mask)[:, None],
                                  ug[jnp.asarray(layout.gather)],
                                  seg, layout.nseg)
    g = jnp.asarray(layout.gather)
    m = jnp.asarray(layout.mask)[:, None]
    return ttmc_pallas(ug[g] * m, xf[g] * m,
                       jnp.asarray(layout.block_seg),
                       jnp.asarray(layout.block_first),
                       layout.nseg, block=layout.block, interpret=interpret)


# --------------------------------------------------------------------------- #
# TTTP leaf:  out[n] = vals[n] * sum_r U[i,r] V[j,r] W[k,r]
# --------------------------------------------------------------------------- #
def tttp(csf: CSFTensor, u: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         block: int = 512, use_pallas: bool = True,
         interpret: bool = True) -> jnp.ndarray:
    fc = csf.fiber_coords(csf.order)
    iidx, jidx, kidx = (jnp.asarray(fc[:, m]) for m in range(3))
    vals = jnp.asarray(csf.values)
    ug, vg, wg = u[iidx], v[jidx], w[kidx]
    if not use_pallas:
        return ref.tttp_ref(vals, ug, vg, wg)
    nnz = vals.shape[0]
    pad = (-nnz) % block
    if pad:
        vals = jnp.pad(vals, (0, pad))
        ug = jnp.pad(ug, ((0, pad), (0, 0)))
        vg = jnp.pad(vg, ((0, pad), (0, 0)))
        wg = jnp.pad(wg, ((0, pad), (0, 0)))
    out = tttp_pallas(vals[:, None], ug, vg, wg, block=block,
                      interpret=interpret)
    return out[:nnz, 0]


# --------------------------------------------------------------------------- #
# passthroughs
# --------------------------------------------------------------------------- #
def grouped_matmul(x, w, use_pallas: bool = True, interpret: bool = True,
                   **kw):
    if not use_pallas:
        return ref.grouped_matmul_ref(x, w)
    return grouped_matmul_pallas(x, w, interpret=interpret, **kw)


def wkv6(r, k, v, w, u, use_pallas: bool = True, interpret: bool = True,
         chunk: int = 128):
    """r/k/v/w (B,T,H,K), u (H,K)."""
    if not use_pallas:
        return ref.wkv6_ref(r, k, v, w, u)
    B, T, H, K = r.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    uu = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    out = wkv6_pallas(fold(r), fold(k), fold(v), fold(w), uu,
                      chunk=min(chunk, T), interpret=interpret)
    return out.reshape(B, H, T, K).transpose(0, 2, 1, 3)


def rglru(x, a, use_pallas: bool = True, interpret: bool = True,
          chunk: int = 256):
    if not use_pallas:
        return ref.rglru_ref(x, a)
    B, T, D = x.shape
    return rglru_pallas(x, a, chunk=min(chunk, T), interpret=interpret)


def local_attn(q, k, v, window: int, use_pallas: bool = True,
               interpret: bool = True, bq: int = 128, bk: int = 128):
    """q/k/v (B,T,H,D)."""
    if not use_pallas:
        return ref.local_attn_ref(q, k, v, window)
    B, T, H, D = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    bq = min(bq, T)
    bk = min(bk, T)
    out = local_attn_pallas(fold(q), fold(k), fold(v), window,
                            bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, T, D).transpose(0, 2, 1, 3)
