"""Pure-jnp oracles for every Pallas kernel (the ref side of each
kernel/ops/ref triple).  These are the semantics the kernels must match
bit-for-bit up to float tolerance, swept over shapes/dtypes in tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mttkrp_ref(vals: jnp.ndarray, bg: jnp.ndarray, cg: jnp.ndarray,
               seg: jnp.ndarray, nseg: int) -> jnp.ndarray:
    """out[s, :] = sum_{n: seg[n]=s} vals[n] * bg[n, :] * cg[n, :]."""
    part = vals[:, None] * bg * cg
    return jax.ops.segment_sum(part, seg, num_segments=nseg)


def ttmc_fiber_ref(xf: jnp.ndarray, ug: jnp.ndarray, seg: jnp.ndarray,
                   nseg: int) -> jnp.ndarray:
    """out[s, r, t] = sum_{f: seg[f]=s} ug[f, r] * xf[f, t]  (fiber outer
    products accumulated per output row — the BLAS-2 xGER of Fig 7)."""
    outer = ug[:, :, None] * xf[:, None, :]
    return jax.ops.segment_sum(outer, seg, num_segments=nseg)


def tttp_ref(vals: jnp.ndarray, ug: jnp.ndarray, vg: jnp.ndarray,
             wg: jnp.ndarray) -> jnp.ndarray:
    """out[n] = vals[n] * sum_r ug[n,r] vg[n,r] wg[n,r]  (TTTP/SDDMM leaf)."""
    return vals * jnp.sum(ug * vg * wg, axis=-1)


def grouped_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(E, C, D) x (E, D, F) -> (E, C, F) batched expert GEMM."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def wkv6_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             w: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """RWKV6 WKV: per head, S_t = diag(exp(-exp(w_t))) S_{t-1} + k_t v_t^T,
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T).

    Shapes: r/k/v/w (B, T, H, K), u (H, K); out (B, T, H, K).
    """
    B, T, H, K = r.shape

    def one_head(rb, kb, vb, wb, uh):
        def step(s, xs):
            rt, kt, vt, wt = xs
            decay = jnp.exp(-jnp.exp(wt))  # data-dependent per-channel decay
            kv = kt[:, None] * vt[None, :]
            out = rt @ (s + uh[:, None] * kv)
            return decay[:, None] * s + kv, out

        _, o = jax.lax.scan(step, jnp.zeros((K, K), r.dtype),
                            (rb, kb, vb, wb))
        return o

    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, T, K)
    uu = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    o = jax.vmap(one_head)(fold(r), fold(k), fold(v), fold(w), uu)
    return o.reshape(B, H, T, K).transpose(0, 2, 1, 3)


def rglru_ref(x: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """RG-LRU linear recurrence: h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * x_t.
    Shapes (B, T, D); returns h (B, T, D).  Associative-scan form."""
    gate = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * x

    def op(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    av, bv = jax.lax.associative_scan(op, (a, gate), axis=1)
    return bv


def local_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   window: int, scale: float | None = None) -> jnp.ndarray:
    """Causal sliding-window attention oracle.  q/k/v: (B, T, H, D)."""
    B, T, H, D = q.shape
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    idx = jnp.arange(T)
    mask = (idx[None, :] <= idx[:, None]) & (idx[None, :] > idx[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
