"""Pallas TPU kernel: fused MTTKRP leaf stage (paper Eq. 1 / Listing 3).

Historically the one hand-fused SpTTN kernel; kernels/codegen/ now emits
this shape of kernel (and every other plan's) generically, so this file's
job is to be the generator's first regression fixture: tests check it and
the generated kernels against ``reference_execute`` on the same inputs.

Computes  out[s, :] += vals[n] * B[j_n, :] * C[k_n, :]  segment-summed over
the static CSF segments.  The factor rows are gathered by XLA outside the
kernel (TPU-native: big fast gathers), while the kernel fuses the 3-way
Hadamard + masked block reduction + output-row accumulation entirely in
VMEM, so the (nnz, R) partials never round-trip to HBM.

Layout: nonzeros are padded per output row to BLOCK multiples (static,
precomputed — see kernels/util.py); the scalar-prefetched ``block_seg``
drives the output BlockSpec, so the sequential TPU grid revisits an output
row block across its nonzero blocks and accumulates in place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 256


def _kernel(block_seg, block_first, vals_ref, bg_ref, cg_ref, mask_ref,
            o_ref):
    b = pl.program_id(0)

    @pl.when(block_first[b] == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = (vals_ref[...] * mask_ref[...]) * bg_ref[...] * cg_ref[...]
    o_ref[...] += jnp.sum(part, axis=0, keepdims=True)


def mttkrp_pallas(vals: jnp.ndarray, bg: jnp.ndarray, cg: jnp.ndarray,
                  mask: jnp.ndarray, block_seg: jnp.ndarray,
                  block_first: jnp.ndarray, nseg: int,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = True) -> jnp.ndarray:
    """All inputs already in padded layout: vals/mask (P, 1), bg/cg (P, R).

    VMEM working set per grid step: (3*block + 1) * R * 4B — e.g.
    block=256, R=128: ~400 KiB, well inside the ~16 MiB v5e VMEM budget;
    R tiles of 128 and block multiples of 8 keep tiles MXU/VPU aligned.
    """
    P, R = bg.shape
    assert P % block == 0
    grid = (P // block,)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, bs, bf: (i, 0)),
            pl.BlockSpec((block, R), lambda i, bs, bf: (i, 0)),
            pl.BlockSpec((block, R), lambda i, bs, bf: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, bs, bf: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, R), lambda i, bs, bf: (bs[i], 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((nseg, R), bg.dtype),
        interpret=interpret,
    )(block_seg, block_first, vals, bg, cg, mask)
