"""Pallas TPU kernel: block-sparse sliding-window flash attention.

The banded causal mask is a *static* sparsity pattern, so the attention
logits are exactly the paper's TTTP/SDDMM kernel with a fixed block-sparse
pattern (DESIGN.md §4): only the W kv-blocks inside the window are ever
visited — the grid itself encodes the sparse iteration space, the way a
CSF loop nest only visits nonzero fibers.

Online-softmax accumulators (m, l, acc) live in VMEM scratch carried over
the kv-block grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, wblocks: int, window: int, scale: float):
    qb = pl.program_id(1)
    wb = pl.program_id(2)

    @pl.when(wb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kvb = qb + wb - (wblocks - 1)  # kv block index (may be < 0: fully masked)

    @pl.when(kvb >= 0)
    def _attend():
        q = q_ref[0] * scale                       # (bq, d)
        k = k_ref[0]                               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kvb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        # block granularity sparsity comes from the grid itself; within a
        # block the exact causal + window element mask applies
        mask = (kpos <= qpos) & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(wb == wblocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def local_attn_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      window: int, bq: int = 128, bk: int = 128,
                      scale: float | None = None,
                      interpret: bool = True) -> jnp.ndarray:
    """q/k/v: (BH, T, D) flattened batch*heads.  Causal sliding window.

    The kv-block axis has ceil(window/bk)+1 steps per q block — compute is
    O(T * window), not O(T^2).  VMEM per step ≈ (bq + 2*bk) * D * 4B +
    bq*(D+2)*4B scratch.
    """
    BH, T, D = q.shape
    assert T % bq == 0 and T % bk == 0
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    # enough kv blocks that qpos - window + 1 is always covered
    wblocks = max(1, min(T // bk, (window + bq - 1) // bk + 1))
    grid = (BH, T // bq, wblocks)

    def kv_index(b, qb, wb):
        kvb = qb + wb - (wblocks - 1)
        return (b, jnp.maximum(kvb, 0), 0)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, wblocks=wblocks,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qb, wb: (b, qb, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qb, wb: (b, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
