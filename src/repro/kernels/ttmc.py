"""Pallas TPU kernel: fused TTMc output stage (paper Eq. 2 / Listing 3 term 2).

Per level-2 fiber f (with output row i_f): OUT[i_f] += U[j_f,:]^T ⊗ X[f,:].
A block of BF fibers belonging to one output row becomes a single MXU
matmul (R x BF) @ (BF x S) — this is the paper's BLAS-2 xGER offload
lifted to a BLAS-3 block (Fig 7), accumulated in the VMEM-resident output
block across the row's fiber blocks (sequential grid revisit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128


def _kernel(block_seg, block_first, ug_ref, xf_ref, o_ref):
    b = pl.program_id(0)

    @pl.when(block_first[b] == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (R, BF) @ (BF, S) on the MXU; padded fibers contribute zero rows.
    o_ref[...] += jax.lax.dot_general(
        ug_ref[...], xf_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype)[None]


def ttmc_pallas(ug: jnp.ndarray, xf: jnp.ndarray, block_seg: jnp.ndarray,
                block_first: jnp.ndarray, nseg: int,
                block: int = DEFAULT_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """ug (P, R) gathered U rows, xf (P, S) fiber intermediates, both in the
    padded per-output-row layout (pads are zero rows).  Output (nseg, R, S).

    VMEM per step: block*(R+S)*4 + R*S*4 — block=128, R=S=128: ~192 KiB.
    """
    P, R = ug.shape
    S = xf.shape[1]
    assert P % block == 0
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P // block,),
        in_specs=[
            pl.BlockSpec((block, R), lambda i, bs, bf: (i, 0)),
            pl.BlockSpec((block, S), lambda i, bs, bf: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, S), lambda i, bs, bf: (bs[i], 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((nseg, R, S), ug.dtype),
        interpret=interpret,
    )(block_seg, block_first, ug, xf)
