"""Pallas TPU kernel: RG-LRU gated linear recurrence (RecurrentGemma).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t     (elementwise, per channel)

Sequential time-chunk grid with the (1, D) hidden state carried in VMEM
scratch; pure VPU work, bandwidth-bound — the kernel exists to keep the
recurrence on-chip instead of materializing scan carries through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256


def _kernel(x_ref, a_ref, o_ref, h, *, chunk: int):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        h[...] = jnp.zeros_like(h)

    def step(t, carry):
        at = a_ref[0, t]
        gated = jnp.sqrt(jnp.clip(1.0 - at * at, 0.0, 1.0)) * x_ref[0, t]
        new = at * carry + gated
        o_ref[0, t] = new
        return new

    h[0] = jax.lax.fori_loop(0, chunk, step, h[0])


def rglru_pallas(x: jnp.ndarray, a: jnp.ndarray,
                 chunk: int = DEFAULT_CHUNK,
                 interpret: bool = True) -> jnp.ndarray:
    """x, a: (B, T, D); returns h: (B, T, D).  T % chunk == 0."""
    B, T, D = x.shape
    assert T % chunk == 0
    grid = (B, T // chunk)
    spec = pl.BlockSpec((1, chunk, D), lambda b, t: (b, t, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(x, a)
