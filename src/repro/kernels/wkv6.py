"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent decay.

Per head:  S_t = diag(exp(-exp(w_t))) S_{t-1} + k_t v_t^T
           o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

The (K, K) state lives in a VMEM scratch carried across the sequential
time-chunk grid axis (TPU grids execute in order — the idiomatic way to
pipeline a linear recurrence).  Within a chunk the time loop runs over
VMEM-resident tiles; outer products and the r-contraction are VPU/MXU ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state, *, chunk: int):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    u = u_ref[0]  # (K,)

    def step(t, s):
        rt = r_ref[0, t]
        kt = k_ref[0, t]
        vt = v_ref[0, t]
        decay = jnp.exp(-jnp.exp(w_ref[0, t]))
        kv = kt[:, None] * vt[None, :]                     # (K, K) outer
        o_ref[0, t] = rt @ (s + u[:, None] * kv)           # (K,) MXU row
        return decay[:, None] * s + kv

    state[...] = jax.lax.fori_loop(0, chunk, step, state[...])


def wkv6_pallas(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                w: jnp.ndarray, u: jnp.ndarray,
                chunk: int = DEFAULT_CHUNK,
                interpret: bool = True) -> jnp.ndarray:
    """r/k/v/w: (BH, T, K) flattened batch*heads; u: (BH, K). Out (BH, T, K).

    VMEM per step: 5 * chunk * K * 4B + K*K*4B scratch — chunk=128, K=64:
    ~180 KiB.  T must be a multiple of chunk.
    """
    BH, T, K = r.shape
    assert T % chunk == 0
    grid = (BH, T // chunk)
    spec = pl.BlockSpec((1, chunk, K), lambda b, t: (b, t, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, K), lambda b, t: (b, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((BH, T, K), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
