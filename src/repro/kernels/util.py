"""Host-side static layout helpers for the Pallas kernels.

The paper's central structural fact — SpTTN sparsity is FIXED — lets us
precompute, once, a block-aligned padded layout per segment (output row):
every nonzero/fiber block then belongs to exactly one output row, so the
TPU kernel is a sequential grid of dense VMEM-resident blocks whose output
BlockSpec is driven by a scalar-prefetched block->row map.  This replaces
the CSF pointer-chasing of the CPU implementation (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PaddedSegments:
    """Block-aligned segment layout (static; computed once per pattern).

    gather:      (P,) int32 — source nonzero index per padded slot (0 for pads)
    mask:        (P,) float32 — 1.0 for real slots, 0.0 for pads
    block_seg:   (P//block,) int32 — output segment of each block
    block_first: (P//block,) int32 — 1 iff block is its segment's first
    nseg, block: ints
    """

    gather: np.ndarray
    mask: np.ndarray
    block_seg: np.ndarray
    block_first: np.ndarray
    nseg: int
    block: int

    @property
    def padded_len(self) -> int:
        return self.gather.shape[0]

    @property
    def nblocks(self) -> int:
        return self.padded_len // self.block


def padded_segment_layout(seg: np.ndarray, nseg: int,
                          block: int) -> PaddedSegments:
    """seg must be sorted ascending (CSF order guarantees this)."""
    seg = np.asarray(seg, dtype=np.int64)
    counts = np.bincount(seg, minlength=nseg)
    # every segment gets at least one block so its output row is zeroed
    padded = np.maximum(block, ((counts + block - 1) // block) * block)
    offs = np.concatenate([[0], np.cumsum(padded)])
    total = int(offs[-1])
    gather = np.zeros(total, dtype=np.int32)
    mask = np.zeros(total, dtype=np.float32)
    if seg.size:
        starts = np.concatenate([[0], np.cumsum(counts)])
        rank = np.arange(seg.size, dtype=np.int64) - starts[seg]
        dst = offs[seg] + rank
        gather[dst] = np.arange(seg.size, dtype=np.int32)
        mask[dst] = 1.0
    nblocks = total // block
    block_seg = np.repeat(np.arange(nseg, dtype=np.int32),
                          (padded // block).astype(np.int64))
    block_first = np.zeros(nblocks, dtype=np.int32)
    first_of_seg = (offs[:-1] // block).astype(np.int64)
    block_first[first_of_seg] = 1
    return PaddedSegments(gather=gather, mask=mask, block_seg=block_seg,
                          block_first=block_first, nseg=nseg, block=block)


def pad_segment_layout(lay: PaddedSegments,
                       padded_len: int) -> PaddedSegments:
    """Extend a layout with inert trailing blocks up to ``padded_len``.

    The stacked distributed engine pads every shard's layout to the
    mesh-wide maximum so one kernel trace serves all shards.  Appended
    slots gather nonzero 0 under mask 0 (contribute nothing) and appended
    blocks replicate the final block's segment id with ``block_first=0``,
    so they re-visit the already-initialized last output row and add an
    all-masked (zero) partial — the output BlockSpec's revisit runs stay
    contiguous and every row keeps its exact value.
    """
    if padded_len == lay.padded_len:
        return lay
    if padded_len < lay.padded_len or padded_len % lay.block:
        raise ValueError(
            f"padded_len {padded_len} must be a multiple of block "
            f"{lay.block} and >= current length {lay.padded_len}")
    extra = padded_len - lay.padded_len
    nblocks = padded_len // lay.block - lay.nblocks
    return PaddedSegments(
        gather=np.pad(lay.gather, (0, extra)),
        mask=np.pad(lay.mask, (0, extra)),
        block_seg=np.pad(lay.block_seg, (0, nblocks), mode="edge"),
        block_first=np.pad(lay.block_first, (0, nblocks)),
        nseg=lay.nseg, block=lay.block)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
