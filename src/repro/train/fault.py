"""Fault tolerance & elasticity logic (cluster-control plane, unit-testable).

On a real fleet the runner wraps each step in ``guarded_step``; on failure
it (1) restores the latest complete checkpoint, (2) rebuilds the mesh from
the surviving device set via ``elastic_mesh_plan``, and (3) resumes the data
stream deterministically from the restored step (data/pipeline.py is
stateless-per-step, so no replay buffer is needed).

Straggler mitigation: ``StragglerMonitor`` keeps an EWMA of step times and
flags outliers; the launcher's response (documented in DESIGN.md §6) is to
re-shard around the slow host at the next checkpoint boundary — here we
implement and test the detection + re-plan math, which is all that can run
without a cluster.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections.abc import Callable

import numpy as np


@dataclasses.dataclass
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    dropped: int


def elastic_mesh_plan(n_devices: int, want_model: int = 16,
                      multi_pod: bool = False) -> MeshPlan:
    """Largest usable mesh for a (possibly degraded) device count.

    Keeps the model axis fixed (TP degree is architectural) and shrinks the
    data axis; devices beyond data*model are left idle — the plan reports
    how many.  A 511-device pod therefore yields (31, 16) + 15 idle, and the
    batch keeps its global size via larger per-device microbatching.
    """
    model = want_model
    while model > 1 and n_devices < model:
        model //= 2
    data = n_devices // model
    if multi_pod and data % 2 == 0 and data >= 2:
        return MeshPlan(shape=(2, data // 2, model),
                        axes=("pod", "data", "model"),
                        dropped=n_devices - data * model)
    return MeshPlan(shape=(data, model), axes=("data", "model"),
                    dropped=n_devices - data * model)


def rebalance_batch(global_batch: int, old_data: int, new_data: int
                    ) -> tuple[int, int]:
    """(per_device_batch, grad_accum) preserving the global batch size."""
    per = global_batch // new_data
    accum = 1
    while per > 0 and per % 2 == 0 and per > global_batch // old_data:
        per //= 2
        accum *= 2
    return max(per, 1), accum


class StragglerMonitor:
    """EWMA step-time outlier detector (z-score on log times)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0):
        self.alpha = alpha
        self.threshold = threshold
        self.mean = None
        self.var = 0.0

    def observe(self, dt: float) -> bool:
        x = math.log(max(dt, 1e-9))
        if self.mean is None:
            self.mean = x
            return False
        z = (x - self.mean) / math.sqrt(self.var + 1e-12)
        a = self.alpha
        self.var = (1 - a) * (self.var + a * (x - self.mean) ** 2)
        self.mean = (1 - a) * self.mean + a * x
        return z > self.threshold


class TransientError(RuntimeError):
    pass


def guarded_step(step_fn: Callable, state, batch, retries: int = 2,
                 on_failure: Callable | None = None):
    """Retry transient failures; escalate to checkpoint-restore via
    ``on_failure`` when retries are exhausted."""
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except TransientError:
            if attempt == retries:
                if on_failure is not None:
                    return on_failure(state, batch)
                raise
            time.sleep(0.01 * (2 ** attempt))
    raise AssertionError("unreachable")


def simulate_failure_schedule(n_steps: int, mtbf_steps: float,
                              seed: int = 0) -> np.ndarray:
    """Poisson failure injection schedule for the integration test."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mtbf_steps, size=max(4, int(n_steps / mtbf_steps)
                                                + 4))
    times = np.cumsum(gaps).astype(np.int64)
    return times[times < n_steps]
