from repro.train import checkpoint, fault, optimizer, train_step
from repro.train.train_step import TrainState, init_train_state, make_train_step

__all__ = ["checkpoint", "fault", "optimizer", "train_step", "TrainState",
           "init_train_state", "make_train_step"]
