"""Sharded, atomic, resumable checkpointing (fault-tolerance substrate).

Layout:   <dir>/step_<N>/shard_<p>.npz  +  manifest.json
  * one npz per host process (each holds its addressable shards — on this
    single-process container that is one file; the format is multi-host);
  * manifest carries step, pytree structure, per-leaf shapes/dtypes and a
    content checksum, written LAST and atomically (tmp + rename) — a crashed
    writer can never produce a manifest pointing at partial data;
  * ``latest_step`` scans for the newest manifest so restart-after-failure
    is a single call;  ``restore`` validates shapes against the live tree.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any

import numpy as np

import jax


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree: Any, directory: str, step: int, process_index: int = 0,
         keep: int = 3) -> str:
    """Write shard + manifest atomically; prune old checkpoints."""
    stepdir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(stepdir, exist_ok=True)
    flat = _flatten(tree)
    shard_path = os.path.join(stepdir, f"shard_{process_index}.npz")
    with tempfile.NamedTemporaryFile(dir=stepdir, delete=False) as tf:
        np.savez(tf, **flat)
        tmp = tf.name
    os.replace(tmp, shard_path)

    checksum = hashlib.sha256()
    for k in sorted(flat):
        checksum.update(k.encode())
        checksum.update(np.ascontiguousarray(flat[k]).tobytes()[:4096])
    manifest = {
        "step": step,
        "n_processes": jax.process_count(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "checksum": checksum.hexdigest(),
    }
    mpath = os.path.join(stepdir, "manifest.json")
    with tempfile.NamedTemporaryFile("w", dir=stepdir, delete=False) as tf:
        json.dump(manifest, tf)
        tmp = tf.name
    os.replace(tmp, mpath)
    _prune(directory, keep)
    return stepdir


def _prune(directory: str, keep: int):
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        stepdir = os.path.join(directory, f"step_{s:08d}")
        for f in os.listdir(stepdir):
            os.unlink(os.path.join(stepdir, f))
        os.rmdir(stepdir)


def latest_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            mpath = os.path.join(directory, name, "manifest.json")
            if os.path.exists(mpath):  # manifest last => complete
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def restore(tree_like: Any, directory: str, step: int | None = None,
            process_index: int = 0) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (validating shapes)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    stepdir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(stepdir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(stepdir, f"shard_{process_index}.npz"))
    flat_live = _flatten(tree_like)
    for k, v in flat_live.items():
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k}")
        if tuple(data[k].shape) != tuple(v.shape):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {data[k].shape} vs live "
                f"{v.shape}")
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    keys = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree_like)[0]]
    new_leaves = [jax.numpy.asarray(data[k]).astype(l.dtype)
                  for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
