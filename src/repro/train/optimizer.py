"""AdamW with fp32 moments, cosine schedule with linear warmup, global-norm
clipping.  Params may be bf16 (moments and the update math stay fp32)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


@dataclasses.dataclass
class AdamWState:
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      step=jnp.zeros((), jnp.int32))


def lr_schedule(run: RunConfig, step):
    warm = jnp.minimum(step / jnp.maximum(run.warmup_steps, 1), 1.0)
    decay = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(
        step / 10_000.0, 1.0)))
    return run.learning_rate * warm * (0.1 + 0.9 * decay)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(params, grads, state: AdamWState, run: RunConfig,
                 b1=0.9, b2=0.95, eps=1e-8):
    grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
    step = state.step + 1
    lr = lr_schedule(run, step.astype(jnp.float32))

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / (1 - b1 ** step.astype(jnp.float32))
        vh = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps) + run.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(m=new_m, v=new_v, step=step), {
        "lr": lr, "grad_norm": gnorm}
