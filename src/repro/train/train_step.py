"""Training step: microbatched grad accumulation (scan) + AdamW + metrics.

The scan-over-microbatches structure is also the compute/communication
overlap mechanism: FSDP all-gathers for microbatch i+1 are independent of
microbatch i's compute, so XLA's latency-hiding scheduler pipelines them.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.transformer import loss_fn
from repro.train.optimizer import AdamWState, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState

    def tree_flatten(self):  # pragma: no cover
        return (self.params, self.opt), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, kids: TrainState(params=kids[0], opt=kids[1]))

jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: ((s.m, s.v, s.step), None),
    lambda _, kids: AdamWState(m=kids[0], v=kids[1], step=kids[2]))


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=init_opt_state(params))


def make_train_step(cfg: ModelConfig, run: RunConfig):
    """Returns step(state, batch) -> (state, metrics)."""
    k = run.microbatches

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=run.remat,
                              unroll=run.scan_unroll),
            has_aux=True)(params)
        return loss, metrics, grads

    def step(state: TrainState, batch):
        params = state.params
        if k == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss_a, g_a = acc
                loss, metrics, grads = grads_of(params, mb)
                g_a = jax.tree.map(jnp.add, g_a, grads)
                return (loss_a + loss, g_a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), micro)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state.opt, run)
        m = {"loss": loss, **opt_metrics}
        return TrainState(params=new_params, opt=new_opt), m

    return step
