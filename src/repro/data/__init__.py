from repro.data import pipeline
from repro.data.pipeline import SyntheticLM, make_loader

__all__ = ["pipeline", "SyntheticLM", "make_loader"]
