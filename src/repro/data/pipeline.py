"""Deterministic, sharded, stateless-per-step data pipeline.

Every (step, shard) pair maps to an independent PRNG stream, so:
  * restart-after-failure resumes mid-stream with zero replay state,
  * elastic re-sharding (fault.py) re-partitions the SAME global stream
    by changing only (n_shards, shard_id),
  * no inter-host coordination is ever needed (straggler-friendly).

The synthetic distribution is Zipf-like over the vocab with Markov
structure so losses are non-trivial; real corpora drop in by replacing
``SyntheticLM`` with a token-file reader that keeps the same
(step, shard) -> batch contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    per_shard_batch: int
    n_shards: int = 1
    shard_id: int = 0
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for (step, shard)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_id]))
        B, S = self.per_shard_batch, self.seq_len
        # zipf-ish marginal + first-order markov dependence
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        tok = base % self.vocab
        shift = rng.integers(0, 17, size=(B, 1))
        tok[:, 1:] = (tok[:, 1:] + (tok[:, :-1] * 31 + shift) % 7) % self.vocab
        tokens = jnp.asarray(tok, jnp.int32)
        return {"tokens": tokens, "labels": tokens}

    def reshard(self, n_shards: int, shard_id: int) -> "SyntheticLM":
        return dataclasses.replace(self, n_shards=n_shards,
                                   shard_id=shard_id)


def make_loader(vocab: int, seq_len: int, global_batch: int,
                n_shards: int = 1, shard_id: int = 0, seed: int = 0):
    per = max(1, global_batch // n_shards)
    ds = SyntheticLM(vocab=vocab, seq_len=seq_len, per_shard_batch=per,
                     n_shards=n_shards, shard_id=shard_id, seed=seed)

    def it(start_step: int = 0):
        step = start_step
        while True:
            yield step, ds.batch_at(step)
            step += 1

    return ds, it
