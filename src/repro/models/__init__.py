from repro.models import attention, layers, moe, recurrent, transformer
from repro.models.transformer import (decode_step, forward, init_cache,
                                      loss_fn, model_init, prefill)

__all__ = ["attention", "layers", "moe", "recurrent", "transformer",
           "decode_step", "forward", "init_cache", "loss_fn", "model_init",
           "prefill"]
