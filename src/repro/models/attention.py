"""Attention variants: GQA/MQA (RoPE, optional bias/qk-norm/sliding window),
DeepSeek-V2 MLA (latent KV), and encoder-decoder cross-attention.  Each has a
full-sequence path (train/prefill) and a single-step decode path over a KV
cache.  Decode shards the KV sequence axis when batch=1 (long-context): the
partial-softmax (numerator, denominator) reduction is associative, so XLA
turns the final combine into one small psum — flash-decode style.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import layers as L

NEG_INF = -2.3819763e38


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, n_kv, hd)  [or latent (B, S, kv_lora+rope) MLA]
    v: jnp.ndarray


# --------------------------------------------------------------------------- #
# GQA
# --------------------------------------------------------------------------- #
def gqa_init(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["q"], s["q"] = L.dense_init(ks[0], d, cfg.n_heads * hd, "embed",
                                  "q_heads", dtype, bias=cfg.qkv_bias)
    p["k"], s["k"] = L.dense_init(ks[1], d, cfg.n_kv_heads * hd, "embed",
                                  "kv_heads", dtype, bias=cfg.qkv_bias)
    p["v"], s["v"] = L.dense_init(ks[2], d, cfg.n_kv_heads * hd, "embed",
                                  "kv_heads", dtype, bias=cfg.qkv_bias)
    p["o"], s["o"] = L.dense_init(ks[3], cfg.n_heads * hd, d, "q_heads",
                                  "embed", dtype)
    if cfg.qk_norm:
        p["qn"], s["qn"] = L.norm_init("rmsnorm", hd, dtype)
        p["kn"], s["kn"] = L.norm_init("rmsnorm", hd, dtype)
    return p, s


def _mask(Tq: int, Tk: int, q_off, window: int | None):
    """Causal(-windowed) mask; ``q_off`` is the position of query row 0.

    A scalar offset (shared decode position / prefill) yields a (Tq, Tk)
    mask; a per-row offset vector (B,) — the continuous-batching server,
    where every slot sits at its own depth — yields (B, Tq, Tk)."""
    q_off = jnp.asarray(q_off)
    if q_off.ndim == 1:
        qpos = q_off[:, None, None] + jnp.arange(Tq)[None, :, None]
        kpos = jnp.arange(Tk)[None, None, :]
    else:
        qpos = q_off + jnp.arange(Tq)[:, None]
        kpos = jnp.arange(Tk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _sdpa(q, k, v, mask, scale):
    # q: (B,Tq,H,D), k/v: (B,Tk,Hkv,D) — grouped heads broadcast;
    # mask is (Tq,Tk) shared or (B,Tq,Tk) per-row (per-slot decode)
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qh = q.reshape(B, Tq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    m = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Tq, H, D)


def _cache_write(cache_leaf, new, update_slice):
    """Write a (B, T, ...) update into the sequence axis of a cache leaf.

    Scalar ``update_slice``: one shared offset (prefill, lockstep decode).
    Vector (B,): per-row offsets — each batch row lands at its own
    position (requires T == 1, the decode step)."""
    if getattr(update_slice, "ndim", 0) == 1:
        B = cache_leaf.shape[0]
        return cache_leaf.at[jnp.arange(B), update_slice].set(
            new[:, 0].astype(cache_leaf.dtype))
    return jax.lax.dynamic_update_slice_in_dim(
        cache_leaf, new.astype(cache_leaf.dtype), update_slice, axis=1)


def gqa_apply(p, cfg: ModelConfig, x, positions, window=None,
              cache: KVCache | None = None, update_slice: int | None = None,
              causal: bool = True):
    """Full-sequence when cache is None; cached prefill/decode otherwise
    (x is (B, T, d) written at offset ``update_slice`` into the cache)."""
    B, T, d = x.shape
    hd = cfg.hd
    q = L.dense(p["q"], x).reshape(B, T, cfg.n_heads, hd)
    k = L.dense(p["k"], x).reshape(B, T, cfg.n_kv_heads, hd)
    v = L.dense(p["v"], x).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.apply_norm("rmsnorm", p["qn"], q)
        k = L.apply_norm("rmsnorm", p["kn"], k)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(hd)

    if cache is None:
        if causal:
            mask = _mask(T, T, 0, window)
        else:
            mask = jnp.ones((T, T), bool)  # bidirectional (encoder)
        out = _sdpa(q, k, v, mask, scale)
        new_cache = KVCache(k=k, v=v)
    else:
        S = cache.k.shape[1]
        if window is not None and S <= window and T == 1:
            # ring-buffer window cache (local layers): O(window) memory
            # instead of O(seq).  Slot s holds position p - ((p - s) mod S);
            # all resident positions are inside the window by construction,
            # only warm-up slots (pos < 0) need masking.
            slot = jnp.mod(update_slice, S)
            kc = _cache_write(cache.k, k, slot)
            vc = _cache_write(cache.v, v, slot)
            s_idx = jnp.arange(S)[None, :]
            if getattr(update_slice, "ndim", 0) == 1:
                us = update_slice[:, None]            # (B, 1)
                slot_pos = us - jnp.mod(us - s_idx, S)
                mask = ((slot_pos >= 0)
                        & (slot_pos > us - window))[:, None, :]  # (B,T=1,S)
            else:
                slot_pos = update_slice - jnp.mod(update_slice - s_idx, S)
                mask = jnp.broadcast_to(
                    (slot_pos >= 0) & (slot_pos > update_slice - window),
                    (T, S))
            out = _sdpa(q, kc.astype(q.dtype), vc.astype(q.dtype), mask,
                        scale)
            new_cache = KVCache(k=kc, v=vc)
        else:
            kc = _cache_write(cache.k, k, update_slice)
            vc = _cache_write(cache.v, v, update_slice)
            # causal-within-prompt: query row t sits at update_slice + t
            mask = _mask(T, S, update_slice, window)
            out = _sdpa(q, kc.astype(q.dtype), vc.astype(q.dtype), mask,
                        scale)
            new_cache = KVCache(k=kc, v=vc)
    y = L.dense(p["o"], out.reshape(B, T, cfg.n_heads * hd))
    return y, new_cache


# --------------------------------------------------------------------------- #
# DeepSeek-V2 MLA
# --------------------------------------------------------------------------- #
def mla_init(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["q_a"], s["q_a"] = L.dense_init(ks[0], d, m.q_lora, "embed", "lora",
                                      dtype)
    p["q_an"], s["q_an"] = L.norm_init("rmsnorm", m.q_lora, dtype)
    p["q_b"], s["q_b"] = L.dense_init(ks[1], m.q_lora, H * qk, "lora",
                                      "q_heads", dtype)
    # kv compression: latent (kv_lora) + decoupled rope key (qk_rope_dim)
    p["kv_a"], s["kv_a"] = L.dense_init(ks[2], d, m.kv_lora + m.qk_rope_dim,
                                        "embed", "lora", dtype)
    p["kv_an"], s["kv_an"] = L.norm_init("rmsnorm", m.kv_lora, dtype)
    p["kv_b"], s["kv_b"] = L.dense_init(
        ks[3], m.kv_lora, H * (m.qk_nope_dim + m.v_head_dim), "lora",
        "q_heads", dtype)
    p["o"], s["o"] = L.dense_init(ks[4], H * m.v_head_dim, d, "q_heads",
                                  "embed", dtype)
    return p, s


def mla_apply_absorbed(p, cfg: ModelConfig, x, positions, cache: KVCache,
                       update_slice):
    """Absorbed-matrix MLA decode (beyond-paper optimization, §Perf).

    Instead of decompressing the whole latent cache through kv_b each step
    (O(S * kv_lora * H * (nope+v)) FLOPs/token), fold W_uk into the query
    and W_uv into the attention output:
        q_lat[h]   = q_nope[h] @ W_uk[h]^T          (kv_lora per head)
        score[h,s] = q_lat[h] . latent[s] + q_rope[h] . k_rope[s]
        ctx_lat[h] = sum_s p[h,s] latent[s]
        out[h]     = ctx_lat[h] @ W_uv[h]
    FLOPs/token drop to O(H * S * kv_lora) — the cache is only ever read at
    its compressed width, which is the entire point of MLA.
    """
    m: MLAConfig = cfg.mla
    B, T, d = x.shape
    assert T == 1, "absorbed path is the single-token decode step"
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    q = L.dense(p["q_b"], L.apply_norm("rmsnorm", p["q_an"],
                                       L.dense(p["q_a"], x)))
    q = q.reshape(B, T, H, qk)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.dense(p["kv_a"], x)
    latent_new = L.apply_norm("rmsnorm", p["kv_an"], kv_a[..., :m.kv_lora])
    k_rope_new = L.apply_rope(kv_a[..., None, m.kv_lora:], positions,
                              cfg.rope_theta)[..., 0, :]
    lat_cat = jnp.concatenate([latent_new, k_rope_new], -1)
    lat_cache = _cache_write(cache.k, lat_cat, update_slice)
    new_cache = KVCache(k=lat_cache, v=cache.v)
    S = lat_cache.shape[1]
    lat_all = lat_cache.astype(q.dtype)
    latent_all = lat_all[..., :m.kv_lora]               # (B,S,kv_lora)
    krope_all = lat_all[..., m.kv_lora:]                # (B,S,rope)

    # fold W_uk (the k_nope decompression) into the query
    w_kv_b = p["kv_b"]["w"].reshape(m.kv_lora, H, m.qk_nope_dim + m.v_head_dim)
    w_uk = w_kv_b[..., :m.qk_nope_dim]                  # (kv_lora,H,nope)
    w_uv = w_kv_b[..., m.qk_nope_dim:]                  # (kv_lora,H,v)
    q_lat = jnp.einsum("bthd,lhd->bthl", q_nope, w_uk)  # (B,1,H,kv_lora)

    scale = 1.0 / math.sqrt(qk)
    lg = (jnp.einsum("bthl,bsl->bhts", q_lat.astype(jnp.float32),
                     latent_all.astype(jnp.float32))
          + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                       krope_all.astype(jnp.float32))) * scale
    mask = _mask(T, S, update_slice, None)
    lg = jnp.where(mask[:, None] if mask.ndim == 3 else mask[None, None],
                   lg, NEG_INF)
    pr = jax.nn.softmax(lg, axis=-1)
    ctx_lat = jnp.einsum("bhts,bsl->bthl", pr.astype(latent_all.dtype),
                         latent_all)                    # (B,1,H,kv_lora)
    out = jnp.einsum("bthl,lhv->bthv", ctx_lat, w_uv)   # (B,1,H,v)
    y = L.dense(p["o"], out.reshape(B, T, H * m.v_head_dim))
    return y, new_cache


def mla_apply(p, cfg: ModelConfig, x, positions,
              cache: KVCache | None = None, update_slice: int | None = None):
    """MLA with latent-KV cache: cache.k stores the (kv_lora + rope) latent
    per token — the 576-dim compressed cache that is MLA's point."""
    if cache is not None and x.shape[1] == 1 and getattr(
            cfg, "mla_absorb", True):
        return mla_apply_absorbed(p, cfg, x, positions, cache, update_slice)
    m: MLAConfig = cfg.mla
    B, T, d = x.shape
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    q = L.dense(p["q_b"], L.apply_norm("rmsnorm", p["q_an"],
                                       L.dense(p["q_a"], x)))
    q = q.reshape(B, T, H, qk)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.dense(p["kv_a"], x)                       # (B,T,kv_lora+rope)
    latent = L.apply_norm("rmsnorm", p["kv_an"], kv_a[..., :m.kv_lora])
    k_rope = L.apply_rope(kv_a[..., None, m.kv_lora:], positions,
                          cfg.rope_theta)              # (B,T,1,rope)
    lat_cat = jnp.concatenate([latent, k_rope[..., 0, :]], -1)

    if cache is not None:
        lat_cat = _cache_write(cache.k, lat_cat, update_slice)
        new_cache = KVCache(k=lat_cat, v=cache.v)
        S = lat_cat.shape[1]
        mask = _mask(T, S, update_slice, None)
    else:
        new_cache = KVCache(k=lat_cat, v=lat_cat[..., :0])
        S = T
        mask = _mask(T, T, 0, None)
    lat_all = lat_cat.astype(q.dtype)
    latent_all, krope_all = lat_all[..., :m.kv_lora], lat_all[..., m.kv_lora:]
    kv = L.dense(p["kv_b"], latent_all).reshape(
        B, S, H, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]

    scale = 1.0 / math.sqrt(qk)
    lg = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                     k_nope.astype(jnp.float32))
          + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                       krope_all.astype(jnp.float32))) * scale
    lg = jnp.where(mask[:, None] if mask.ndim == 3 else mask[None, None],
                   lg, NEG_INF)
    pr = jax.nn.softmax(lg, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr.astype(v.dtype), v)
    y = L.dense(p["o"], out.reshape(B, T, H * m.v_head_dim))
    return y, new_cache


# --------------------------------------------------------------------------- #
# Cross-attention (enc-dec)
# --------------------------------------------------------------------------- #
def cross_init(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["q"], s["q"] = L.dense_init(ks[0], d, cfg.n_heads * hd, "embed",
                                  "q_heads", dtype)
    p["k"], s["k"] = L.dense_init(ks[1], d, cfg.n_kv_heads * hd, "embed",
                                  "kv_heads", dtype)
    p["v"], s["v"] = L.dense_init(ks[2], d, cfg.n_kv_heads * hd, "embed",
                                  "kv_heads", dtype)
    p["o"], s["o"] = L.dense_init(ks[3], cfg.n_heads * hd, d, "q_heads",
                                  "embed", dtype)
    return p, s


def cross_apply(p, cfg: ModelConfig, x, enc_out):
    B, T, d = x.shape
    S = enc_out.shape[1]
    hd = cfg.hd
    q = L.dense(p["q"], x).reshape(B, T, cfg.n_heads, hd)
    k = L.dense(p["k"], enc_out).reshape(B, S, cfg.n_kv_heads, hd)
    v = L.dense(p["v"], enc_out).reshape(B, S, cfg.n_kv_heads, hd)
    mask = jnp.ones((T, S), bool)
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(hd))
    return L.dense(p["o"], out.reshape(B, T, cfg.n_heads * hd))
