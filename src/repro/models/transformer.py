"""Model assembly for all assigned architectures.

One generic decoder (optionally encoder-decoder) built from typed blocks:
  attn   — global causal GQA/MLA + FFN (dense or MoE)
  local  — sliding-window GQA + FFN
  rglru  — RecurrentGemma recurrent block + FFN
  rwkv   — RWKV6 time-mix + channel-mix

Layers are executed as jax.lax.scan over *repeating pattern groups* (e.g.
gemma3's 5 local + 1 global) so the lowered HLO stays one-group-sized
regardless of depth — essential for 512-way SPMD compile times.  Remainder
layers (depth % group) and MoE "first dense" layers are unrolled.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import replicate, shard_activation
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def block_init(kind: str, key, cfg: ModelConfig, dtype, ffn: str = "dense"):
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["n1"], s["n1"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
    if kind in ("attn", "local", "enc_attn", "xattn"):
        if cfg.mla is not None and kind in ("attn", "xattn"):
            p["mix"], s["mix"] = A.mla_init(ks[0], cfg, dtype)
        else:
            p["mix"], s["mix"] = A.gqa_init(ks[0], cfg, dtype)
        if kind == "xattn":
            p["n_x"], s["n_x"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
            p["cross"], s["cross"] = A.cross_init(ks[2], cfg, dtype)
    elif kind == "rglru":
        p["mix"], s["mix"] = R.rglru_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["mix"], s["mix"] = R.rwkv6_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    p["n2"], s["n2"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
    if kind != "rwkv":  # rwkv's channel-mix lives inside its mix params
        if ffn == "moe":
            p["ffn"], s["ffn"] = M.moe_init(ks[1], cfg, dtype)
        elif ffn.startswith("dense"):
            d_ff = cfg.d_ff if ffn == "dense" else int(ffn.split(":")[1])
            p["ffn"], s["ffn"] = L.mlp_init(ks[1], cfg.mlp, cfg.d_model,
                                            d_ff, dtype)
    if cfg.post_norms:
        p["pn1"], s["pn1"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        p["pn2"], s["pn2"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
    return p, s


def block_apply(kind: str, p, cfg: ModelConfig, x, positions,
                state=None, update_slice=None, enc_out=None,
                ffn: str = "dense", train: bool = True):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg.norm, p["n1"], x)
    if kind in ("attn", "local", "enc_attn", "xattn"):
        window = cfg.window if kind == "local" else None
        if cfg.mla is not None and kind in ("attn", "xattn"):
            y, new_state = A.mla_apply(p["mix"], cfg, h, positions,
                                       cache=state, update_slice=update_slice)
        else:
            causal = kind != "enc_attn"
            y, new_state = A.gqa_apply(p["mix"], cfg, h, positions,
                                       window=window, cache=state,
                                       update_slice=update_slice,
                                       causal=causal)
            if not causal:
                new_state = None
    elif kind == "rglru":
        y, new_state = R.rglru_apply(p["mix"], cfg, h, state)
    elif kind == "rwkv":
        tm_state = None if state is None else (state[0], state[1])
        y, tm_new = R.rwkv6_time_mix(p["mix"], cfg, h, tm_state)
        x = x + y
        h2 = L.apply_norm(cfg.norm, p["n2"], x)
        cm_prev = None if state is None else state[2]
        y2, cm_new = R.rwkv6_channel_mix(p["mix"], cfg, h2, cm_prev)
        x = x + y2
        x = shard_activation(x, "btd")
        new_state = None if state is None else (tm_new[0], tm_new[1], cm_new)
        return x, new_state, aux
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        y = L.apply_norm(cfg.norm, p["pn1"], y)
    x = x + y
    if kind == "xattn" and enc_out is not None:
        x = x + A.cross_apply(p["cross"],
                              cfg, L.apply_norm(cfg.norm, p["n_x"], x),
                              enc_out)
    h = L.apply_norm(cfg.norm, p["n2"], x)
    if ffn == "moe":
        y, aux = M.moe_apply(p["ffn"], cfg, h, train=train)
    else:
        y = L.mlp_apply(cfg.mlp, p["ffn"], h)
    if cfg.post_norms:
        y = L.apply_norm(cfg.norm, p["pn2"], y)
    x = x + y
    x = shard_activation(x, "btd")
    return x, new_state, aux


# --------------------------------------------------------------------------- #
# layer plan: which layers scan, which unroll
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class LayerPlan:
    head: tuple[tuple[str, str], ...]   # (kind, ffn) unrolled leading layers
    group: tuple[tuple[str, str], ...]  # repeating scanned group
    n_groups: int
    tail: tuple[tuple[str, str], ...]   # unrolled remainder


def layer_plan(cfg: ModelConfig, decoder: bool = True) -> LayerPlan:
    n = cfg.n_layers
    kinds = cfg.pattern_for_layers(n)
    if cfg.encdec and decoder:
        kinds = ["xattn"] * n
    ffns = []
    for i in range(n):
        if cfg.moe is not None:
            if i < cfg.moe.first_dense:
                ffns.append(f"dense:{cfg.moe.d_first_dense}")
            else:
                ffns.append("moe")
        else:
            ffns.append("dense")
    layers = list(zip(kinds, ffns))
    head_n = cfg.moe.first_dense if cfg.moe is not None else 0
    head, rest = tuple(layers[:head_n]), layers[head_n:]
    g = len(cfg.block_pattern) if not (cfg.encdec and decoder) else 1
    n_groups = len(rest) // g
    scanned, tail = rest[: n_groups * g], tuple(rest[n_groups * g:])
    group = tuple(scanned[:g]) if n_groups else ()
    return LayerPlan(head=head, group=group, n_groups=n_groups, tail=tail)


def _stack_init(key, cfg, plan: LayerPlan, dtype):
    """Init head/tail unrolled + per-group-position stacked params."""
    p, s = {"head": [], "tail": []}, {"head": [], "tail": []}
    keys = jax.random.split(key, len(plan.head) + len(plan.tail) + 1)
    ki = 0
    for kind, ffn in plan.head:
        bp, bs = block_init(kind, keys[ki], cfg, dtype, ffn)
        p["head"].append(bp)
        s["head"].append(bs)
        ki += 1
    for kind, ffn in plan.tail:
        bp, bs = block_init(kind, keys[ki], cfg, dtype, ffn)
        p["tail"].append(bp)
        s["tail"].append(bs)
        ki += 1
    if plan.n_groups:
        scan_p, scan_s = {}, {}
        gkeys = jax.random.split(keys[ki], plan.n_groups * len(plan.group))
        for j, (kind, ffn) in enumerate(plan.group):
            per = [block_init(kind, gkeys[g * len(plan.group) + j], cfg,
                              dtype, ffn)
                   for g in range(plan.n_groups)]
            scan_p[f"b{j}"] = L.stack_params([pp for pp, _ in per])
            scan_s[f"b{j}"] = L.stack_specs(per[0][1])
        p["scan"], s["scan"] = scan_p, scan_s
    return p, s


def _stack_apply(p, cfg, plan: LayerPlan, x, positions, caches=None,
                 update_slice=None, enc_out=None, remat: bool = True,
                 unroll: bool = False, train: bool = True):
    """Apply head (unrolled) + scanned groups + tail.  ``caches`` mirrors the
    param structure; returns (x, new_caches, aux_sum).  ``unroll=True``
    replaces lax.scan with a python loop (used by the dry-run cost probes,
    where XLA's HloCostAnalysis counts while bodies only once)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict[str, Any] = {"head": [], "tail": []}
    for i, (kind, ffn) in enumerate(plan.head):
        st = None if caches is None else caches["head"][i]
        x, ns, aux = block_apply(kind, p["head"][i], cfg, x, positions, st,
                                 update_slice, enc_out, ffn, train)
        new_caches["head"].append(ns)
        aux_total += aux

    if plan.n_groups and unroll:
        new_scan_list = []
        for g in range(plan.n_groups):
            params_g = jax.tree.map(lambda a: a[g], p["scan"])
            cache_g = (None if caches is None else
                       jax.tree.map(lambda a: a[g], caches["scan"]))
            new_cache_g = {}
            for j, (kind, ffn) in enumerate(plan.group):
                st = None if cache_g is None else cache_g[f"b{j}"]
                x, ns, aux = block_apply(kind, params_g[f"b{j}"], cfg, x,
                                         positions, st, update_slice,
                                         enc_out, ffn, train)
                new_cache_g[f"b{j}"] = ns if ns is not None else 0
                aux_total = aux_total + aux
            new_scan_list.append(new_cache_g)
        if caches is not None:
            new_caches["scan"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *new_scan_list)
        else:
            new_caches["scan"] = None
    elif plan.n_groups:
        def group_body(carry, xs):
            x, auxc = carry
            params_g, cache_g = xs
            new_cache_g = {}
            for j, (kind, ffn) in enumerate(plan.group):
                st = None if cache_g is None else cache_g[f"b{j}"]
                x, ns, aux = block_apply(kind, params_g[f"b{j}"], cfg, x,
                                         positions, st, update_slice,
                                         enc_out, ffn, train)
                new_cache_g[f"b{j}"] = ns if ns is not None else 0
                auxc = auxc + aux
            return (x, auxc), new_cache_g

        body = jax.checkpoint(group_body) if remat else group_body
        cache_xs = None if caches is None else caches["scan"]
        if cache_xs is None:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, pg: body(c, (pg, None)),
                (x, aux_total), p["scan"])
            new_caches["scan"] = None
        else:
            (x, aux_total), new_scan = jax.lax.scan(
                body, (x, aux_total), (p["scan"], cache_xs))
            new_caches["scan"] = new_scan

    for i, (kind, ffn) in enumerate(plan.tail):
        st = None if caches is None else caches["tail"][i]
        x, ns, aux = block_apply(kind, p["tail"][i], cfg, x, positions, st,
                                 update_slice, enc_out, ffn, train)
        new_caches["tail"].append(ns)
        aux_total += aux
    return x, new_caches, aux_total


# --------------------------------------------------------------------------- #
# full model
# --------------------------------------------------------------------------- #
def model_init(key, cfg: ModelConfig):
    """Returns (params, specs)."""
    dtype = cfg.compute_dtype
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    # vocab padded to a TP-divisible multiple (§Perf: granite's 49155-row
    # table replicated the logits matmul 16x before padding)
    p["embed"], s["embed"] = L.embed_init(ks[0], cfg.padded_vocab,
                                          cfg.d_model, dtype)
    plan = layer_plan(cfg, decoder=True)
    p["dec"], s["dec"] = _stack_init(ks[1], cfg, plan, dtype)
    if cfg.encdec:
        enc_plan = LayerPlan(head=(), group=(("enc_attn", "dense"),),
                             n_groups=cfg.n_enc_layers, tail=())
        p["enc"], s["enc"] = _stack_init(ks[2], cfg, enc_plan, dtype)
        p["enc_norm"], s["enc_norm"] = L.norm_init(cfg.norm, cfg.d_model,
                                                   dtype)
    p["final_norm"], s["final_norm"] = L.norm_init(cfg.norm, cfg.d_model,
                                                   dtype)
    if not cfg.tie_embeddings:
        p["head"], s["head"] = L.dense_init(ks[3], cfg.d_model,
                                            cfg.padded_vocab,
                                            "embed", "vocab", dtype)
    return p, s


def _encode(p, cfg: ModelConfig, enc_frames, remat=True, unroll=False):
    enc_plan = LayerPlan(head=(), group=(("enc_attn", "dense"),),
                         n_groups=cfg.n_enc_layers, tail=())
    pos = jnp.broadcast_to(jnp.arange(enc_frames.shape[1]),
                           enc_frames.shape[:2])
    x, _, _ = _stack_apply(p["enc"], cfg, enc_plan, enc_frames, pos,
                           remat=remat, unroll=unroll)
    return L.apply_norm(cfg.norm, p["enc_norm"], x)


def _embed_inputs(p, cfg: ModelConfig, batch):
    # many-token lookups (train/prefill): all-gather the vocab-sharded
    # table once — GSPMD's one-hot-matmul lowering costs ~2*N*V*D FLOPs
    # (§Perf).  Few-token lookups (decode) keep the sharded gather.
    table = p["embed"]["w"]
    if batch["tokens"].size >= table.shape[0]:
        table = replicate(table)
    x = L.embed_lookup({"w": table}, batch["tokens"])
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.modality_stub == "vision" and "stub" in batch:
        n = batch["stub"].shape[1]
        x = jnp.concatenate([batch["stub"].astype(x.dtype), x[:, n:]], 1)
    return x


def forward(p, cfg: ModelConfig, batch, remat: bool = True,
            unroll: bool = False, train: bool = False):
    """Full-sequence forward: returns (logits, aux_loss).

    ``train=True`` (set by :func:`loss_fn`) enables capacity-bounded MoE
    dispatch; the default is inference semantics (dropless MoE), which keeps
    a batched forward consistent with prefill + decode_step."""
    x = _embed_inputs(p, cfg, batch)
    x = shard_activation(x, "btd")
    B, T = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    enc_out = None
    if cfg.encdec:
        enc_out = _encode(p, cfg, batch["enc_frames"].astype(x.dtype),
                          remat=remat, unroll=unroll)
    plan = layer_plan(cfg, decoder=True)
    x, _, aux = _stack_apply(p["dec"], cfg, plan, x, positions,
                             enc_out=enc_out, remat=remat, unroll=unroll,
                             train=train)
    x = L.apply_norm(cfg.norm, p["final_norm"], x)
    logits = _logits(p, cfg, x)
    return logits, aux


def _logits(p, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = x @ p["embed"]["w"].T
    else:
        logits = L.dense(p["head"], x)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab:
        # mask padding columns (keeps the vocab dim sharded; slicing would
        # force a gather)
        cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(cols < cfg.vocab, logits, -1e30)
    return logits


def loss_fn(p, cfg: ModelConfig, batch, remat: bool = True,
            unroll: bool = False):
    logits, aux = forward(p, cfg, batch, remat=remat, unroll=unroll,
                          train=True)
    targets = batch["labels"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = targets[:, 1:]
    logz = jax.nn.logsumexp(logits, -1)
    # vocab-parallel gold lookup: a masked reduction keeps the vocab dim
    # sharded (take_along_axis would all-gather the full logits — §Perf)
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    gold = jnp.sum(jnp.where(cols == targets[..., None], logits, 0.0), -1)
    ce = (logz - gold).mean()
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------- #
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------- #
def _one_cache(kind: str, cfg: ModelConfig, B: int, S: int, dtype,
               ring: bool = True):
    hd = cfg.hd
    if kind in ("attn", "local", "xattn"):
        if cfg.mla is not None and kind in ("attn", "xattn"):
            m = cfg.mla
            lat = jnp.zeros((B, S, m.kv_lora + m.qk_rope_dim), dtype)
            return A.KVCache(k=lat, v=jnp.zeros((B, S, 0), dtype))
        if kind == "local" and ring and cfg.window is not None:
            # ring-buffer cache: O(window) per local layer (§Perf)
            S = min(S, cfg.window)
        return A.KVCache(
            k=jnp.zeros((B, S, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((B, S, cfg.n_kv_heads, hd), dtype))
    if kind == "rglru":
        return (jnp.zeros((B, 3, cfg.d_model), dtype),
                jnp.zeros((B, cfg.d_model), dtype))
    if kind == "rwkv":
        H = cfg.d_model // 64
        return (jnp.zeros((B, cfg.d_model), dtype),
                jnp.zeros((B, H, 64, 64), dtype),
                jnp.zeros((B, cfg.d_model), dtype))
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, B: int, S: int, dtype=None,
               ring: bool = True):
    dtype = dtype or cfg.compute_dtype
    plan = layer_plan(cfg, decoder=True)
    caches: dict[str, Any] = {
        "head": [_one_cache(k, cfg, B, S, dtype, ring) for k, _ in plan.head],
        "tail": [_one_cache(k, cfg, B, S, dtype, ring) for k, _ in plan.tail],
    }
    if plan.n_groups:
        scan_c = {}
        for j, (kind, _) in enumerate(plan.group):
            one = _one_cache(kind, cfg, B, S, dtype, ring)
            scan_c[f"b{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (plan.n_groups,) + a.shape), one)
        caches["scan"] = scan_c
    else:
        caches["scan"] = None
    return caches


def decode_step(p, cfg: ModelConfig, caches, tokens, pos, enc_out=None,
                unroll: bool = False):
    """One token step: tokens (B, 1), pos int32 position — a scalar when
    every row decodes in lockstep, or a per-row vector (B,) when rows sit
    at different depths (the continuous-batching server with mixed-length
    prompts).  Returns (logits (B,1,V), new_caches)."""
    table = p["embed"]["w"]
    if tokens.size >= table.shape[0]:
        table = replicate(table)
    x = L.embed_lookup({"w": table}, tokens)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    B = tokens.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = (pos[:, None] if pos.ndim == 1
                 else jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32))
    plan = layer_plan(cfg, decoder=True)
    x, new_caches, _ = _stack_apply(p["dec"], cfg, plan, x, positions,
                                    caches=caches, update_slice=pos,
                                    enc_out=enc_out, remat=False,
                                    unroll=unroll, train=False)
    x = L.apply_norm(cfg.norm, p["final_norm"], x)
    return _logits(p, cfg, x), new_caches


def prefill(p, cfg: ModelConfig, batch, cache_len: int | None = None,
            remat: bool = False, unroll: bool = False):
    """Prefill: forward over the prompt, building caches sized cache_len."""
    B, T = batch["tokens"].shape
    S = cache_len or T
    caches = init_cache(cfg, B, S, ring=False)  # prefill writes T>1 rows
    x = _embed_inputs(p, cfg, batch)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    enc_out = None
    if cfg.encdec:
        enc_out = _encode(p, cfg, batch["enc_frames"].astype(x.dtype),
                          remat=remat, unroll=unroll)
    plan = layer_plan(cfg, decoder=True)
    x, new_caches, _ = _stack_apply(p["dec"], cfg, plan, x, positions,
                                    caches=caches,
                                    update_slice=jnp.asarray(0, jnp.int32),
                                    enc_out=enc_out, remat=remat,
                                    unroll=unroll, train=False)
    x = L.apply_norm(cfg.norm, p["final_norm"], x)
    return _logits(p, cfg, x[:, -1:]), new_caches
