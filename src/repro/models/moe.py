"""Mixture-of-Experts layer with SpTTN-planned dispatch (DESIGN.md §4).

The routing tensor D(t, e, c) (token t -> expert e at capacity slot c) is a
sparse tensor with nnz = top_k * n_tokens and a *static shape* per step, and
MoE dispatch/combine are exactly SpTTN kernels:

    dispatch:  Xe(e,c,d) = sum_t  D(t,e,c) * X(t,d)
    combine:   Y(t,m)    = sum_ec D(t,e,c) * Ye(e,c,m)

``choose_dispatch`` builds these specs and runs the paper's planner: the
"unfactorized" schedule is the dense one-hot einsum (O(N*E*C*D)); the
factorize-and-fuse schedule iterates the nnz only — i.e. the sort-based
capacity dispatch + grouped GEMM implemented below (O(N*k*D)).  The planner's
FLOP model picks the latter for every real configuration; both paths are
implemented and equivalence-tested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers as L


@functools.lru_cache(maxsize=64)
def choose_dispatch(n_tokens: int, n_experts: int, top_k: int,
                    capacity: int, d_model: int) -> str:
    """Consult the SpTTN planner for the dispatch schedule ('grouped' or
    'onehot').  Cached per kernel signature (pattern-static, as in §5)."""
    from repro.core.cost import path_flops
    from repro.core.paths import min_depth_paths
    from repro.core.spec import parse

    spec = parse("tec,td->ecd",
                 dims={"t": n_tokens, "e": n_experts, "c": capacity,
                       "d": d_model}, sparse=0, names=["D", "X"])
    nnz = {0: 1, 1: n_tokens, 2: n_tokens * top_k, 3: n_tokens * top_k}
    sparse_flops = min(path_flops(p, spec.dims, spec.sparse_indices, nnz)
                       for p in min_depth_paths(spec))
    dense_flops = 2.0 * n_tokens * n_experts * capacity * d_model
    return "grouped" if sparse_flops < dense_flops else "onehot"


def moe_init(key, cfg: ModelConfig, dtype):
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["router"], s["router"] = L.dense_init(ks[0], d, m.n_experts, "embed",
                                            "experts", dtype)
    def expert_w(key, din, dout):
        w = (jax.random.normal(key, (m.n_experts, din, dout), jnp.float32)
             / jnp.sqrt(din)).astype(dtype)
        return w
    p["w_gate"] = expert_w(ks[1], d, m.d_expert)
    s["w_gate"] = ("experts", "embed", "ffn")
    p["w_up"] = expert_w(ks[2], d, m.d_expert)
    s["w_up"] = ("experts", "embed", "ffn")
    p["w_down"] = expert_w(ks[3], m.d_expert, d)
    s["w_down"] = ("experts", "ffn", "embed")
    if m.n_shared:
        p["shared"], s["shared"] = L.mlp_init(
            ks[4], "swiglu", d, m.n_shared * m.d_shared, dtype)
    return p, s


def _capacity(m: MoEConfig, n_tokens: int) -> int:
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 (sublane aligned)


def _route(p, m: MoEConfig, x2d):
    logits = L.dense(p["router"], x2d).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, m.top_k)          # (N,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    aux = _load_balance_loss(probs, idx, m.n_experts)
    return gate, idx, aux


def _load_balance_loss(probs, idx, E):
    N = idx.shape[0]
    frac_tokens = jnp.zeros((E,), jnp.float32).at[idx[:, 0]].add(1.0) / N
    frac_probs = probs.mean(0)
    return E * jnp.sum(frac_tokens * frac_probs)


def _expert_ffn(p, xe):
    """xe (E, C, D) -> (E, C, D) SwiGLU via grouped GEMMs (MXU batched)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_apply(p, cfg: ModelConfig, x, deterministic_dispatch: str | None = None,
              train: bool = True):
    """x (B, T, D) -> (y, aux_loss).  Dispatch mode from the SpTTN planner
    unless overridden by cfg.moe.dispatch / deterministic_dispatch.

    ``train=False`` (inference) uses *dropless* capacity: slots are assigned
    in token order, so capacity overflow in a batched forward drops exactly
    the trailing tokens — the ones a later decode step recomputes without
    batch contention.  Dropless inference keeps prefill/decode consistent
    with a batched forward (DESIGN.md §5).  Per-expert load is at most N
    (top-k expert ids are distinct per token), so C = N suffices.
    """
    m: MoEConfig = cfg.moe
    B, T, D = x.shape
    N = B * T
    x2d = x.reshape(N, D)
    C = _capacity(m, N) if train else max(8, -(-N // 8) * 8)
    mode = deterministic_dispatch or m.dispatch
    if mode == "auto":
        mode = choose_dispatch(N, m.n_experts, m.top_k, C, D)

    gate, idx, aux = _route(p, m, x2d)

    if mode == "onehot":
        y = _apply_onehot(p, m, x2d, gate, idx, C)
    else:
        y = _apply_grouped(p, m, x2d, gate, idx, C)

    if m.n_shared:
        y = y + L.mlp_apply("swiglu", p["shared"], x2d)
    return y.reshape(B, T, D), aux


def _apply_onehot(p, m: MoEConfig, x2d, gate, idx, C):
    """Unfactorized baseline: dense one-hot dispatch einsum (the schedule
    TACO/COMET would default to; kept for planner validation + tests)."""
    N, D = x2d.shape
    # D(t,e,c): one-hot over experts x capacity slots.  Dispatch uses the
    # unweighted pattern; the gate weights enter at combine (after the
    # nonlinear expert FFN), matching the grouped schedule exactly.
    pos = _slot_positions(idx, m.n_experts, C)         # (N,k) slot or -1
    disp = jnp.zeros((N, m.n_experts, C), x2d.dtype)
    dispw = jnp.zeros((N, m.n_experts, C), x2d.dtype)
    for j in range(m.top_k):
        valid = pos[:, j] >= 0
        t = jnp.arange(N)
        e = idx[:, j]
        c = jnp.clip(pos[:, j], 0, C - 1)
        disp = disp.at[t, e, c].add(
            jnp.where(valid, 1.0, 0.0).astype(x2d.dtype))
        dispw = dispw.at[t, e, c].add(
            jnp.where(valid, gate[:, j].astype(x2d.dtype), 0.0))
    xe = jnp.einsum("tec,td->ecd", disp, x2d)
    ye = _expert_ffn(p, xe)
    return jnp.einsum("tec,ecd->td", dispw, ye)


def _slot_positions(idx, E, C):
    """Capacity-slot index per (token, choice); -1 when over capacity.

    Sort-based ranking, O(Nk log Nk) time and O(Nk) memory — this IS the
    CSF construction for the routing tensor: sorting the nnz of D(t,e,c)
    into (e, slot) storage order, done per step since routing is dynamic
    (the *shapes* stay static, so the schedule is still pattern-static).
    """
    N, k = idx.shape
    flat = idx.reshape(-1)                              # (Nk,) expert ids
    Nk = flat.shape[0]
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat].add(1)
    starts = jnp.cumsum(counts) - counts                # first slot per expert
    rank_sorted = jnp.arange(Nk, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((Nk,), jnp.int32).at[order].set(rank_sorted)
    pos = jnp.where(rank < C, rank, -1)
    return pos.reshape(N, k)


def _apply_grouped(p, m: MoEConfig, x2d, gate, idx, C):
    """Factorize-and-fuse schedule from the SpTTN planner: iterate only the
    nnz of D (sorted by expert = CSF order on (e, c)) + grouped GEMM."""
    N, D = x2d.shape
    E = m.n_experts
    pos = _slot_positions(idx, E, C)                    # (N,k)
    token = jnp.broadcast_to(jnp.arange(N)[:, None], idx.shape).reshape(-1)
    expert = idx.reshape(-1)
    slot = pos.reshape(-1)
    w = gate.reshape(-1).astype(x2d.dtype)
    valid = slot >= 0
    dst = expert * C + jnp.clip(slot, 0, C - 1)         # (N*k,) slot addr
    dst = jnp.where(valid, dst, E * C)                  # overflow -> dump row
    # dispatch: scatter token rows into (E*C (+1), D)
    xe = jnp.zeros((E * C + 1, D), x2d.dtype).at[dst].add(
        x2d[token] * valid[:, None].astype(x2d.dtype))
    from repro.distributed.sharding import shard_activation
    xe3 = shard_activation(xe[:-1].reshape(E, C, D), "ecd")
    ye = shard_activation(_expert_ffn(p, xe3), "ecd").reshape(E * C, D)
    # combine: gather slots back per (token, choice), weight, sum over k
    ye_pad = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], 0)
    contrib = ye_pad[dst] * (w * valid.astype(x2d.dtype))[:, None]
    y = jnp.zeros((N, D), x2d.dtype).at[token].add(contrib)
    return y
