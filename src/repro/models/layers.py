"""Functional layer library: every init returns (params, logical-axis specs).

Params are plain pytrees (nested dicts of jnp arrays).  The parallel `specs`
tree holds tuples of *logical axis names* per array; distributed/sharding.py
maps logical names -> mesh axes per mesh/shape (MaxText-style rules).
"""
from __future__ import annotations

import math
from collections.abc import Callable

import jax
import jax.numpy as jnp


def _norm_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def dense_init(key, d_in: int, d_out: int, in_axis: str, out_axis: str,
               dtype, bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    s = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (out_axis,)
    return p, s


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype):
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return {"w": w}, {"w": ("vocab", "embed")}


def embed_lookup(p, ids):
    return p["w"][ids]


def norm_init(kind: str, d: int, dtype):
    if kind == "nonparam_ln":       # OLMo: no learned affine
        return {}, {}
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def apply_norm(kind: str, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        if p:
            y = y * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if p:
            y = y * p["scale"].astype(jnp.float32)
    elif kind == "nonparam_ln":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., T, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def mlp_init(key, kind: str, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        p, s = {}, {}
        p["gate"], s["gate"] = dense_init(ks[0], d, d_ff, "embed", "ffn", dtype)
        p["up"], s["up"] = dense_init(ks[1], d, d_ff, "embed", "ffn", dtype)
        p["down"], s["down"] = dense_init(ks[2], d_ff, d, "ffn", "embed", dtype)
        return p, s
    p, s = {}, {}
    p["up"], s["up"] = dense_init(ks[0], d, d_ff, "embed", "ffn", dtype)
    p["down"], s["down"] = dense_init(ks[1], d_ff, d, "ffn", "embed", dtype)
    return p, s


def mlp_apply(kind: str, p, x):
    if kind == "swiglu":
        return dense(p["down"], jax.nn.silu(dense(p["gate"], x))
                     * dense(p["up"], x))
    if kind == "geglu":
        return dense(p["down"], jax.nn.gelu(dense(p["gate"], x))
                     * dense(p["up"], x))
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


# --------------------------------------------------------------------------- #
# spec/tree utilities
# --------------------------------------------------------------------------- #
def stack_params(plist):
    """Stack per-layer param trees along a new leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *plist)


def stack_specs(spec):
    """Prepend the 'layers' logical axis to every spec tuple."""
    return jax.tree.map(lambda s: ("layers",) + tuple(s), spec,
                        is_leaf=lambda s: isinstance(s, tuple))


def abstract_init(init_fn: Callable, *args, **kwargs):
    """eval_shape an init so dry-runs never allocate real parameters."""
    return jax.eval_shape(init_fn, *args, **kwargs)
