"""Recurrent blocks: RecurrentGemma's RG-LRU and RWKV6 (Finch) time/channel
mix.  Full-sequence paths use associative scans (XLA); decode paths carry
O(1) state.  The Pallas kernels (kernels/rglru.py, kernels/wkv6.py) are the
TPU-target implementations of the same math (validated against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

C_RGLRU = 8.0


# --------------------------------------------------------------------------- #
# RG-LRU recurrent block (RecurrentGemma)
# --------------------------------------------------------------------------- #
def rglru_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["in_x"], s["in_x"] = L.dense_init(ks[0], d, d, "embed", "ffn", dtype)
    p["in_g"], s["in_g"] = L.dense_init(ks[1], d, d, "embed", "ffn", dtype)
    p["conv_w"] = (jax.random.normal(ks[2], (4, d), jnp.float32)
                   * 0.02).astype(dtype)
    s["conv_w"] = ("conv", "ffn")
    p["gate_a"], s["gate_a"] = L.dense_init(ks[3], d, d, "ffn", "ffn", dtype,
                                            bias=True)
    p["gate_x"], s["gate_x"] = L.dense_init(ks[4], d, d, "ffn", "ffn", dtype,
                                            bias=True)
    lam = jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, d)))  # softplus^-1(a)
    p["log_a"] = lam.astype(jnp.float32)
    s["log_a"] = ("ffn",)
    p["out"], s["out"] = L.dense_init(ks[5], d, d, "ffn", "embed", dtype)
    return p, s


def _causal_conv(w, x, state=None):
    """width-4 depthwise causal conv; state (B, 3, D) for decode."""
    K = w.shape[0]
    if state is None:
        pads = jnp.zeros_like(x[:, : K - 1])
        xp = jnp.concatenate([pads, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return out, new_state


def rglru_apply(p, cfg: ModelConfig, x, state=None):
    """state = (conv_state (B,3,D), h (B,D)) for decode; None for train."""
    gate_branch = jax.nn.gelu(L.dense(p["in_g"], x))
    xb = L.dense(p["in_x"], x)
    conv_state = None if state is None else state[0]
    xb, new_conv = _causal_conv(p["conv_w"], xb, conv_state)

    r = jax.nn.sigmoid(L.dense(p["gate_a"], xb).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense(p["gate_x"], xb))
    log_a = -C_RGLRU * r * jax.nn.softplus(p["log_a"])  # log a_t  (<0)
    a = jnp.exp(log_a).astype(x.dtype)
    gated_x = i * xb

    h0 = None if state is None else state[1].astype(x.dtype)
    h = _lin_rec_scan(a, gated_x, h0)
    new_h = h[:, -1]
    y = L.dense(p["out"], h * gate_branch)
    return y, (new_conv, new_h)


def _lin_rec_scan(a, x, h0=None):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t via associative scan over T,
    with optional initial state h0 folded in as h_t += (prod a_1..t) h0."""
    mult = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0))

    def op(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    cum_a, h = jax.lax.associative_scan(op, (a, mult * x), axis=1)
    if h0 is not None:
        h = h + cum_a * h0[:, None]
    return h


# --------------------------------------------------------------------------- #
# RWKV6 block (time-mix + channel-mix)
# --------------------------------------------------------------------------- #
def rwkv6_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H = d // 64                    # head size 64 (RWKV convention)
    K = 64
    ks = jax.random.split(key, 10)
    p, s = {}, {}
    for i, nm in enumerate(("r", "k", "v", "g")):
        p[nm], s[nm] = L.dense_init(ks[i], d, d, "embed", "ffn", dtype)
        p[f"mu_{nm}"] = jnp.full((d,), 0.5, dtype)
        s[f"mu_{nm}"] = ("embed",)
    p["w_lora_a"], s["w_lora_a"] = L.dense_init(ks[4], d, 64, "embed",
                                                "lora", dtype)
    p["w_lora_b"], s["w_lora_b"] = L.dense_init(ks[5], 64, d, "lora",
                                                "ffn", dtype)
    p["mu_w"] = jnp.full((d,), 0.5, dtype)
    s["mu_w"] = ("embed",)
    p["w_base"] = jnp.full((d,), -5.0, jnp.float32)
    s["w_base"] = ("ffn",)
    p["u"] = (jax.random.normal(ks[6], (H, K), jnp.float32) * 0.1)
    s["u"] = ("heads", "head_dim")
    p["out"], s["out"] = L.dense_init(ks[7], d, d, "ffn", "embed", dtype)
    p["ln_x"], s["ln_x"] = L.norm_init("layernorm", d, dtype)
    # channel-mix
    p["cm_k"], s["cm_k"] = L.dense_init(ks[8], d, cfg.d_ff, "embed", "ffn",
                                        dtype)
    p["cm_v"], s["cm_v"] = L.dense_init(ks[9], cfg.d_ff, d, "ffn", "embed",
                                        dtype)
    p["mu_cm"] = jnp.full((d,), 0.5, dtype)
    s["mu_cm"] = ("embed",)
    return p, s


def _token_shift(x, prev=None):
    """shift(x)_t = x_{t-1}; ``prev`` (B, D) is the last token of the
    previous segment (decode/chunked-prefill state)."""
    if prev is None:
        first = jnp.zeros_like(x[:, :1])
    else:
        first = prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _wkv6_with_state(r, k, v, w, u, s0):
    """lax.scan WKV6 that threads an explicit (B,H,K,K) state (prefill and
    decode paths; the stateless train path uses kernels/ref.wkv6_ref)."""
    B, T, H, K = r.shape

    def step(s, xs):
        rt, kt, vt, wt = xs                     # (B,H,K) each
        decay = jnp.exp(-jnp.exp(wt.astype(jnp.float32)))
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32),
                        vt.astype(jnp.float32))
        o = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                       s + u[None, :, :, None] * kv)
        return decay[..., None] * s + kv, o

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))  # (T,B,H,K)
    s_fin, o = jax.lax.scan(step, s0, xs)
    return o.transpose(1, 0, 2, 3), s_fin       # (B,T,H,K), (B,H,K,K)


def rwkv6_time_mix(p, cfg: ModelConfig, x, state=None):
    """state = (x_prev (B,D), wkv_state (B,H,K,K)) for decode/prefill."""
    B, T, d = x.shape
    H, K = d // 64, 64
    prev = None if state is None else state[0]
    xx = _token_shift(x, prev)

    def mix(nm):
        return x + (xx - x) * p[f"mu_{nm}"]

    r = L.dense(p["r"], mix("r")).reshape(B, T, H, K)
    k = L.dense(p["k"], mix("k")).reshape(B, T, H, K)
    v = L.dense(p["v"], mix("v")).reshape(B, T, H, K)
    g = jax.nn.silu(L.dense(p["g"], mix("g")))
    w = (p["w_base"]
         + L.dense(p["w_lora_b"],
                   jnp.tanh(L.dense(p["w_lora_a"], mix("w")))).astype(
                       jnp.float32))
    w = w.reshape(B, T, H, K).astype(x.dtype)

    if state is None:
        from repro.kernels.ref import wkv6_ref
        o = wkv6_ref(r, k, v, w, p["u"].astype(x.dtype))
        new_wkv = None  # stateless training path
    else:
        s0 = state[1].astype(jnp.float32)
        o, new_wkv = _wkv6_with_state(r, k, v, w,
                                      p["u"].astype(jnp.float32), s0)
        o = o.astype(x.dtype)
    o = o.reshape(B, T, d)
    o = L.apply_norm("layernorm", p["ln_x"], o)
    y = L.dense(p["out"], o * g)
    return y, (x[:, -1], new_wkv)


def rwkv6_channel_mix(p, cfg: ModelConfig, x, state=None):
    xx = _token_shift(x, state)
    xk = x + (xx - x) * p["mu_cm"]
    k = jnp.square(jax.nn.relu(L.dense(p["cm_k"], xk)))
    return L.dense(p["cm_v"], k), x[:, -1]
